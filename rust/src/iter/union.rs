//! `Concurrently` / `Union` — composing concurrently executing dataflow
//! fragments (paper §4 Concurrency, Figure 8; used by Ape-X and the
//! multi-agent PPO+DQN composition).

use std::sync::mpsc;

use super::LocalIter;

#[derive(Debug, Clone)]
pub enum UnionMode {
    /// Pull children in a fixed rotation on the driver.  `weights[i]`
    /// pulls are taken from child i per cycle — the rate-limiting knob
    /// (Acme-style fixed-ratio progress, paper §2.2/§4).  `None` = 1
    /// pull each.  Deterministic and fully lazy.
    RoundRobin { weights: Option<Vec<usize>> },
    /// Drive every child from its own driver thread, yielding items as
    /// they become ready (maximum pipeline overlap — Ape-X's
    /// mode="async").  Each child is driven at most `buffer` items ahead
    /// of consumption (bounded channels provide the backpressure the
    /// paper's scheduler applies to concurrent fragments).
    Async { buffer: usize },
}

/// Compose concurrent sub-flows into one iterator.
///
/// `output_indexes`: if set, items from children not listed are still
/// *driven* (their side effects happen) but dropped from the output —
/// e.g. Ape-X emits only sub-flow (3)'s items (`output_indexes=[2]`).
pub fn concurrently<T: Send + 'static>(
    children: Vec<LocalIter<T>>,
    mode: UnionMode,
    output_indexes: Option<Vec<usize>>,
) -> LocalIter<T> {
    let emit = move |idx: usize| {
        output_indexes.as_ref().map_or(true, |s| s.contains(&idx))
    };
    match mode {
        UnionMode::RoundRobin { weights } => {
            let weights = match weights {
                Some(w) => {
                    assert_eq!(w.len(), children.len(), "weights length");
                    assert!(w.iter().all(|&x| x >= 1), "weights must be >= 1");
                    w
                }
                None => vec![1; children.len()],
            };
            round_robin(children, weights, emit)
        }
        UnionMode::Async { buffer } => async_union(children, buffer, emit),
    }
}

fn round_robin<T: Send + 'static>(
    children: Vec<LocalIter<T>>,
    weights: Vec<usize>,
    emit: impl Fn(usize) -> bool + Send + 'static,
) -> LocalIter<T> {
    let mut children: Vec<Option<LocalIter<T>>> =
        children.into_iter().map(Some).collect();
    let mut cursor = 0usize;
    let mut left_in_cycle = weights[0];
    LocalIter::from_fn(move || loop {
        if children.iter().all(|c| c.is_none()) {
            return None;
        }
        if children[cursor].is_none() || left_in_cycle == 0 {
            cursor = (cursor + 1) % children.len();
            left_in_cycle = weights[cursor];
            continue;
        }
        match children[cursor].as_mut().unwrap().next() {
            Some(t) => {
                left_in_cycle -= 1;
                let idx = cursor;
                if left_in_cycle == 0 {
                    cursor = (cursor + 1) % children.len();
                    left_in_cycle = weights[cursor];
                }
                if emit(idx) {
                    return Some(t);
                }
                // Driven but dropped: keep pulling.
            }
            None => {
                children[cursor] = None;
                cursor = (cursor + 1) % children.len();
                left_in_cycle = weights[cursor];
            }
        }
    })
}

fn async_union<T: Send + 'static>(
    children: Vec<LocalIter<T>>,
    buffer: usize,
    emit: impl Fn(usize) -> bool + Send + 'static,
) -> LocalIter<T> {
    assert!(buffer >= 1);
    struct State<T> {
        rx: mpsc::Receiver<(usize, Option<T>)>,
        live: usize,
    }
    let mut lazy: Option<State<T>> = None;
    let mut children = Some(children);
    LocalIter::from_fn(move || {
        let st = lazy.get_or_insert_with(|| {
            // First pull: spawn one driver thread per child.  The
            // bounded channel means each child runs at most `buffer`
            // items ahead of the consumer.
            let children = children.take().unwrap();
            let (tx, rx) = mpsc::sync_channel(buffer);
            let live = children.len();
            for (i, mut child) in children.into_iter().enumerate() {
                let tx = tx.clone();
                std::thread::Builder::new()
                    .name(format!("union-{i}"))
                    .spawn(move || loop {
                        let item = child.next();
                        let end = item.is_none();
                        if tx.send((i, item)).is_err() || end {
                            return;
                        }
                    })
                    .expect("spawn union driver");
            }
            State { rx: to_receiver(rx), live }
        });
        loop {
            if st.live == 0 {
                return None;
            }
            match st.rx.recv() {
                Ok((idx, Some(t))) => {
                    if emit(idx) {
                        return Some(t);
                    }
                }
                Ok((_, None)) => st.live -= 1,
                Err(_) => return None,
            }
        }
    })
}

/// `sync_channel` gives a `Receiver` already; helper for type clarity.
fn to_receiver<T>(rx: mpsc::Receiver<T>) -> mpsc::Receiver<T> {
    rx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_alternates() {
        let a = LocalIter::from_items(vec![1, 3, 5]);
        let b = LocalIter::from_items(vec![2, 4, 6]);
        let got = concurrently(
            vec![a, b],
            UnionMode::RoundRobin { weights: None },
            None,
        )
        .collect();
        assert_eq!(got, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn round_robin_weights_rate_limit() {
        // 2 pulls from a per 1 from b — fixed 2:1 progress ratio.
        let a = LocalIter::from_items(vec![10, 11, 12, 13]);
        let b = LocalIter::from_items(vec![20, 21]);
        let got = concurrently(
            vec![a, b],
            UnionMode::RoundRobin { weights: Some(vec![2, 1]) },
            None,
        )
        .collect();
        assert_eq!(got, vec![10, 11, 20, 12, 13, 21]);
    }

    #[test]
    fn round_robin_continues_after_exhaustion() {
        let a = LocalIter::from_items(vec![1]);
        let b = LocalIter::from_items(vec![2, 3, 4]);
        let got = concurrently(
            vec![a, b],
            UnionMode::RoundRobin { weights: None },
            None,
        )
        .collect();
        assert_eq!(got, vec![1, 2, 3, 4]);
    }

    #[test]
    fn output_indexes_drive_but_drop() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let driven = Arc::new(AtomicUsize::new(0));
        let d = driven.clone();
        let mut n = 0;
        let store_op = LocalIter::from_fn(move || {
            n += 1;
            if n > 3 {
                return None;
            }
            d.fetch_add(1, Ordering::SeqCst);
            Some(0) // side-effecting subflow, output dropped
        });
        let update_op = LocalIter::from_items(vec![100, 200, 300]);
        let got = concurrently(
            vec![store_op, update_op],
            UnionMode::RoundRobin { weights: None },
            Some(vec![1]),
        )
        .collect();
        assert_eq!(got, vec![100, 200, 300]);
        assert_eq!(driven.load(Ordering::SeqCst), 3); // side effects ran
    }

    #[test]
    fn async_mode_yields_everything() {
        let a = LocalIter::from_items(vec![1, 2]);
        let b = LocalIter::from_items(vec![3]);
        let mut got =
            concurrently(vec![a, b], UnionMode::Async { buffer: 4 }, None)
                .collect();
        got.sort();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn async_mode_with_output_indexes() {
        let a = LocalIter::from_items(vec![1, 2, 3]);
        let b = LocalIter::from_items(vec![10, 20]);
        let got = concurrently(
            vec![a, b],
            UnionMode::Async { buffer: 2 },
            Some(vec![0]),
        )
        .collect();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn async_mode_overlaps_slow_children() {
        // One slow and one fast child: total wall-clock must be far
        // below the serial sum (true concurrency).
        let slow = LocalIter::from_items(vec![1, 2, 3, 4]).for_each(|x| {
            std::thread::sleep(std::time::Duration::from_millis(25));
            x
        });
        let fast = LocalIter::from_items(vec![10, 20, 30, 40]).for_each(|x| {
            std::thread::sleep(std::time::Duration::from_millis(25));
            x
        });
        let start = std::time::Instant::now();
        let got = concurrently(
            vec![slow, fast],
            UnionMode::Async { buffer: 2 },
            None,
        )
        .collect();
        assert_eq!(got.len(), 8);
        let elapsed = start.elapsed();
        assert!(
            elapsed < std::time::Duration::from_millis(190),
            "children did not overlap: {elapsed:?}"
        );
    }

    #[test]
    fn empty_children_end_immediately() {
        let a = LocalIter::from_items(Vec::<i32>::new());
        let got = concurrently(
            vec![a],
            UnionMode::RoundRobin { weights: None },
            None,
        )
        .collect();
        assert!(got.is_empty());
    }
}
