//! The parallel-iterator library — the general-purpose half of RLlib
//! Flow (paper Figure 2: "parallel iterator library, 1241 LoC").
//!
//! Two iterator kinds, both *lazy* (nothing executes until `next()` is
//! awaited on the terminal iterator, paper §4):
//!
//! * [`ParIter<W, T>`] — a parallel stream sharded across a set of actors
//!   of state type `W`.  Transformations added with
//!   [`ParIter::for_each`] execute **on the source actor** (the paper's
//!   locality rule: `ComputeGradients` reads the worker-local policy
//!   state), composing into a single per-shard plan closure.
//! * [`LocalIter<T>`] — a sequential stream on the driver, produced by
//!   the *sequencing operators* [`ParIter::gather_async`] (pink arrows:
//!   items arrive as ready, `num_async` controls pipelining) and
//!   [`ParIter::gather_sync`] (black arrows: barrier rounds — one item
//!   per shard per round, upstream fully halted between fetches, so
//!   actor messages sent between fetches are ordered w.r.t. dataflow).
//!
//! Concurrency across dataflow fragments is composed with
//! [`concurrently`] (the paper's `Union`/`Concurrently` operator:
//! round-robin, rate-limited round-robin via weights, or fully async),
//! and [`LocalIter::duplicate`] (the `split` operator with buffering).

mod local;
mod par;
mod union;

pub use local::LocalIter;
pub use par::{DeadlineSupervision, ParIter};
pub use union::{concurrently, UnionMode};
