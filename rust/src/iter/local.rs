//! `LocalIter<T>` — the sequential, driver-side iterator (`Iter[T]` in
//! the paper).
//!
//! Pull-based and lazy (Volcano-style): a `LocalIter` is a boxed
//! `FnMut() -> Option<T>` plan; nothing upstream executes until `next()`
//! is called on the terminal iterator.  Parallelism lives in the actor
//! threads upstream (see `ParIter`) — the driver side is deliberately a
//! simple blocking pull, which is exactly RLlib Flow's execution model
//! (the driver drives the plan; workers compute).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

type NextFn<T> = Box<dyn FnMut() -> Option<T> + Send>;

pub struct LocalIter<T> {
    next_fn: NextFn<T>,
}

impl<T: Send + 'static> LocalIter<T> {
    /// A source driven by a closure (None ends the stream).
    pub fn from_fn(f: impl FnMut() -> Option<T> + Send + 'static) -> Self {
        LocalIter { next_fn: Box::new(f) }
    }

    /// A finite source from a vector.
    pub fn from_items(items: Vec<T>) -> Self {
        let mut q: VecDeque<T> = items.into();
        Self::from_fn(move || q.pop_front())
    }

    /// Pull the next item, driving the whole upstream plan.
    pub fn next(&mut self) -> Option<T> {
        (self.next_fn)()
    }

    /// Drain the stream into a vector (tests/benches).
    pub fn collect(mut self) -> Vec<T> {
        let mut out = Vec::new();
        while let Some(t) = self.next() {
            out.push(t);
        }
        out
    }

    /// Transform each item with a (possibly stateful) closure — the
    /// paper's sequential `for_each`.  Stateful ops hold their state in
    /// the closure (paper §4 Transformation).
    pub fn for_each<U: Send + 'static>(
        self,
        mut f: impl FnMut(T) -> U + Send + 'static,
    ) -> LocalIter<U> {
        let mut src = self;
        LocalIter::from_fn(move || src.next().map(&mut f))
    }

    /// Keep items satisfying the predicate.
    pub fn filter(
        self,
        mut pred: impl FnMut(&T) -> bool + Send + 'static,
    ) -> LocalIter<T> {
        let mut src = self;
        LocalIter::from_fn(move || loop {
            match src.next() {
                Some(t) if pred(&t) => return Some(t),
                Some(_) => continue,
                None => return None,
            }
        })
    }

    /// Transform-and-drop: `None` results are skipped without ending
    /// the stream (e.g. `Replay` before learning-starts).
    pub fn filter_map<U: Send + 'static>(
        self,
        mut f: impl FnMut(T) -> Option<U> + Send + 'static,
    ) -> LocalIter<U> {
        let mut src = self;
        LocalIter::from_fn(move || loop {
            match src.next() {
                Some(t) => {
                    if let Some(u) = f(t) {
                        return Some(u);
                    }
                }
                None => return None,
            }
        })
    }

    /// A stateful accumulate-and-emit transform: `op` returns any number
    /// of output items per input (the paper's `combine`, used by
    /// `ConcatBatches`: buffer until the target size, then emit one).
    pub fn combine<U: Send + 'static>(
        self,
        mut op: impl FnMut(T) -> Vec<U> + Send + 'static,
    ) -> LocalIter<U> {
        let mut src = self;
        let mut pending: VecDeque<U> = VecDeque::new();
        LocalIter::from_fn(move || loop {
            if let Some(u) = pending.pop_front() {
                return Some(u);
            }
            match src.next() {
                Some(t) => pending.extend(op(t)),
                None => return None,
            }
        })
    }

    /// End the stream after `n` items.
    pub fn take(self, n: usize) -> LocalIter<T> {
        let mut src = self;
        let mut left = n;
        LocalIter::from_fn(move || {
            if left == 0 {
                return None;
            }
            left -= 1;
            src.next()
        })
    }

    /// Duplicate into two consumers (the paper's `split`).  Items are
    /// buffered per consumer until consumed; a pull happens on behalf of
    /// whichever consumer runs dry first, so buffering grows only with
    /// the consumption imbalance (the memory-bounding rule from §4
    /// Concurrency).
    pub fn duplicate(self) -> (LocalIter<T>, LocalIter<T>)
    where
        T: Clone,
    {
        let shared = Arc::new(Mutex::new(SplitState {
            upstream: self,
            buffers: [VecDeque::new(), VecDeque::new()],
            done: false,
        }));
        let a = shared.clone();
        (
            LocalIter::from_fn(move || split_next(&a, 0)),
            LocalIter::from_fn(move || split_next(&shared, 1)),
        )
    }
}

struct SplitState<T> {
    upstream: LocalIter<T>,
    buffers: [VecDeque<T>; 2],
    done: bool,
}

fn split_next<T: Clone + Send + 'static>(
    shared: &Arc<Mutex<SplitState<T>>>,
    side: usize,
) -> Option<T> {
    let mut st = shared.lock().unwrap();
    if let Some(item) = st.buffers[side].pop_front() {
        return Some(item);
    }
    if st.done {
        return None;
    }
    match st.upstream.next() {
        Some(item) => {
            st.buffers[1 - side].push_back(item.clone());
            Some(item)
        }
        None => {
            st.done = true;
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_items_yields_in_order() {
        let mut it = LocalIter::from_items(vec![1, 2, 3]);
        assert_eq!(it.next(), Some(1));
        assert_eq!(it.next(), Some(2));
        assert_eq!(it.next(), Some(3));
        assert_eq!(it.next(), None);
    }

    #[test]
    fn for_each_is_lazy_and_stateful() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let mut sum = 0; // stateful closure
        let mut it = LocalIter::from_items(vec![1, 2, 3]).for_each(move |x| {
            c.fetch_add(1, Ordering::SeqCst);
            sum += x;
            sum
        });
        assert_eq!(count.load(Ordering::SeqCst), 0); // laziness
        assert_eq!(it.next(), Some(1));
        assert_eq!(it.next(), Some(3));
        assert_eq!(count.load(Ordering::SeqCst), 2);
        assert_eq!(it.next(), Some(6));
    }

    #[test]
    fn filter_drops_items() {
        let it = LocalIter::from_items((0..10).collect()).filter(|x| x % 3 == 0);
        assert_eq!(it.collect(), vec![0, 3, 6, 9]);
    }

    #[test]
    fn filter_map_skips_none_without_ending() {
        let it = LocalIter::from_items(vec![1, 2, 3, 4])
            .filter_map(|x| if x % 2 == 0 { Some(x * 10) } else { None });
        assert_eq!(it.collect(), vec![20, 40]);
    }

    #[test]
    fn combine_accumulates_like_concat_batches() {
        let mut buf = vec![];
        let mut it = LocalIter::from_items((1..=7).collect()).combine(move |x| {
            buf.push(x);
            if buf.len() >= 3 {
                vec![std::mem::take(&mut buf)]
            } else {
                vec![]
            }
        });
        assert_eq!(it.next(), Some(vec![1, 2, 3]));
        assert_eq!(it.next(), Some(vec![4, 5, 6]));
        assert_eq!(it.next(), None); // tail never reached 3
    }

    #[test]
    fn combine_can_fan_out() {
        let it = LocalIter::from_items(vec![2, 3])
            .combine(|x| (0..x).collect::<Vec<_>>());
        assert_eq!(it.collect(), vec![0, 1, 0, 1, 2]);
    }

    #[test]
    fn take_ends_stream() {
        let mut n = 0;
        let it = LocalIter::from_fn(move || {
            n += 1;
            Some(n)
        })
        .take(3);
        assert_eq!(it.collect(), vec![1, 2, 3]);
    }

    #[test]
    fn duplicate_gives_both_consumers_all_items() {
        let (mut a, mut b) = LocalIter::from_items(vec![1, 2, 3]).duplicate();
        assert_eq!(a.next(), Some(1));
        assert_eq!(b.next(), Some(1));
        assert_eq!(b.next(), Some(2));
        assert_eq!(b.next(), Some(3));
        assert_eq!(b.next(), None);
        assert_eq!(a.next(), Some(2));
        assert_eq!(a.next(), Some(3));
        assert_eq!(a.next(), None);
    }

    #[test]
    fn duplicate_buffers_only_the_imbalance() {
        let (mut a, mut b) = LocalIter::from_items((0..100).collect()).duplicate();
        for _ in 0..10 {
            a.next();
        }
        for i in 0..10 {
            assert_eq!(b.next(), Some(i));
        }
    }
}
