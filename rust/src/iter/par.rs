//! `ParIter<W, T>` — the parallel iterator (`ParIter[T]`), sharded over a
//! set of actors of state type `W`.
//!
//! A `ParIter` is a *plan*: a list of shard actors plus one composed
//! closure that, when invoked **on the actor**, produces the next item.
//! `for_each` extends the plan (still on-actor); the `gather_*`
//! sequencing operators are the only places execution is driven.
//!
//! Both gather modes ride one shared bounded [`CompletionQueue`] (the
//! batched-`ray.wait` analog): shards deliver results into it with
//! `call_into`, and its bound — `shards x num_async` for `gather_async`,
//! `shards` for `gather_sync` — is exactly the in-flight budget, so
//! `num_async` is a real flow-control knob, not a hint.  A shard whose
//! actor dies (panics) delivers a death notice instead of a value; the
//! gather marks it exhausted and the stream continues off the surviving
//! shards rather than panicking the driver (restart policy lives with
//! the owner, e.g. `WorkerSet::restart_dead`).

use std::sync::Arc;

use crate::actor::{ActorHandle, Completion, CompletionQueue};

use super::LocalIter;

type PlanFn<W, T> = Arc<dyn Fn(&mut W) -> Option<T> + Send + Sync>;

pub struct ParIter<W: 'static, T> {
    shards: Vec<ActorHandle<W>>,
    plan: PlanFn<W, T>,
}

impl<W: 'static, T: Send + 'static> Clone for ParIter<W, T> {
    fn clone(&self) -> Self {
        ParIter { shards: self.shards.clone(), plan: self.plan.clone() }
    }
}

impl<W: 'static, T: Send + 'static> ParIter<W, T> {
    /// Create a parallel iterator from a set of source actors and a
    /// source function (e.g. "sample a batch from this rollout worker").
    /// Returning `None` ends that shard.
    pub fn from_actors(
        shards: Vec<ActorHandle<W>>,
        source: impl Fn(&mut W) -> Option<T> + Send + Sync + 'static,
    ) -> Self {
        assert!(!shards.is_empty(), "ParIter needs at least one shard");
        ParIter { shards, plan: Arc::new(source) }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[ActorHandle<W>] {
        &self.shards
    }

    /// Parallel transformation, scheduled **onto the source actor** so
    /// the op can read/write worker-local state (paper §4
    /// Transformation; `ComputeGradients` relies on this locality).
    pub fn for_each<U: Send + 'static>(
        self,
        op: impl Fn(&mut W, T) -> U + Send + Sync + 'static,
    ) -> ParIter<W, U> {
        let plan = self.plan;
        ParIter {
            shards: self.shards,
            plan: Arc::new(move |w| plan(w).map(|t| op(w, t))),
        }
    }

    /// Sequencing operator, async mode (pink arrow): items are merged
    /// into the sequential iterator *as soon as they are ready*, in
    /// nondeterministic order.  `num_async` requests are kept in flight
    /// per shard (the pipeline-parallelism knob, paper §3) via the
    /// shared completion queue.
    pub fn gather_async(self, num_async: usize) -> LocalIter<T> {
        self.gather_async_with_source(num_async).for_each(|(t, _)| t)
    }

    /// `gather_async` + `zip_with_source_actor`: each item is paired
    /// with the handle of the shard actor that produced it (used by
    /// Ape-X's `UpdateWorkerWeights` to message the producing worker).
    pub fn gather_async_with_source(
        self,
        num_async: usize,
    ) -> LocalIter<(T, ActorHandle<W>)> {
        assert!(num_async >= 1);
        struct State<W: 'static, T: Send + 'static> {
            shards: Vec<ActorHandle<W>>,
            plan: PlanFn<W, T>,
            queue: CompletionQueue<Option<T>>,
            outstanding: usize,
            shard_done: Vec<bool>,
            started: bool,
        }
        impl<W: 'static, T: Send + 'static> State<W, T> {
            /// Submit one plan invocation to shard `idx`.  Every
            /// submission yields exactly one completion (value or death
            /// notice), so `outstanding` can never leak.
            fn submit(&mut self, idx: usize) {
                let plan = self.plan.clone();
                self.shards[idx].call_into(idx, &self.queue, move |w| plan(w));
                self.outstanding += 1;
            }
        }
        let n = self.shards.len();
        let mut st = State {
            queue: CompletionQueue::bounded((n * num_async).max(1)),
            shards: self.shards,
            plan: self.plan,
            outstanding: 0,
            shard_done: vec![false; n],
            started: false,
        };
        LocalIter::from_fn(move || {
            if !st.started {
                st.started = true;
                // Prime the pipeline: num_async calls in flight per shard.
                for i in 0..st.shards.len() {
                    for _ in 0..num_async {
                        st.submit(i);
                    }
                }
            }
            loop {
                if st.outstanding == 0 {
                    return None;
                }
                let completion = st.queue.pop();
                st.outstanding -= 1;
                match completion {
                    Completion::Item { tag, value: Some(t) }
                        if !st.shard_done[tag] =>
                    {
                        // Refill the shard's pipeline slot.
                        st.submit(tag);
                        return Some((t, st.shards[tag].clone()));
                    }
                    Completion::Item { value: Some(_), .. } => {
                        // Late result from a pipelined call issued before
                        // the shard reported exhaustion: drop it.
                    }
                    Completion::Item { tag, value: None } => {
                        st.shard_done[tag] = true;
                    }
                    Completion::Dropped { tag } => {
                        // Shard actor died; retire it and keep pulling
                        // from the survivors.
                        st.shard_done[tag] = true;
                    }
                }
            }
        })
    }

    /// Sequencing operator, sync mode (black arrow): each `next()`
    /// issues one call to **every** live shard, waits for all of them
    /// (executing in parallel across actor threads), and yields the
    /// round as a `Vec` in shard order.  Upstream is fully halted
    /// between fetches — barrier semantics, so actor messages sent
    /// between fetches (e.g. a weight broadcast) are ordered with
    /// respect to dataflow steps (paper §4 Sequencing).  Ends when any
    /// shard is exhausted; a shard whose actor *died* is dropped from
    /// subsequent rounds instead (the stream ends when none survive).
    pub fn gather_sync(self) -> LocalIter<Vec<T>> {
        let n = self.shards.len();
        let shards = self.shards;
        let plan = self.plan;
        let queue: CompletionQueue<Option<T>> =
            CompletionQueue::bounded(n.max(1));
        let mut alive = vec![true; n];
        let mut done = false;
        LocalIter::from_fn(move || {
            if done {
                return None;
            }
            let mut issued = 0usize;
            for (i, shard) in shards.iter().enumerate() {
                if alive[i] {
                    let plan = plan.clone();
                    shard.call_into(i, &queue, move |w| plan(w));
                    issued += 1;
                }
            }
            if issued == 0 {
                done = true;
                return None;
            }
            // Collect the whole round (reassembled into shard order so
            // barrier plans stay deterministic) before deciding.
            let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
            for _ in 0..issued {
                match queue.pop() {
                    Completion::Item { tag, value: Some(t) } => {
                        slots[tag] = Some(t);
                    }
                    Completion::Item { value: None, .. } => done = true,
                    Completion::Dropped { tag } => alive[tag] = false,
                }
            }
            if done {
                return None;
            }
            let round: Vec<T> = slots.into_iter().flatten().collect();
            if round.is_empty() {
                done = true;
                return None;
            }
            Some(round)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::spawn_group;

    struct Worker {
        id: usize,
        counter: i32,
        weights: f32,
    }

    fn workers(n: usize) -> Vec<ActorHandle<Worker>> {
        spawn_group("w", n, |i| {
            Box::new(move || Worker { id: i, counter: 0, weights: 0.0 })
        })
    }

    #[test]
    fn for_each_runs_on_source_actor() {
        let ws = workers(2);
        let it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            Some(w.counter)
        })
        // The op reads actor-local state (w.id): proves on-actor exec.
        .for_each(|w, c| (w.id, c));
        let mut gathered = it.gather_sync();
        let round = gathered.next().unwrap();
        let mut ids: Vec<usize> = round.iter().map(|(id, _)| *id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1]);
        assert!(round.iter().all(|(_, c)| *c == 1));
    }

    #[test]
    fn plan_is_lazy_until_gathered() {
        let ws = workers(1);
        let _plan = ParIter::from_actors(ws.clone(), |w: &mut Worker| {
            w.counter += 1;
            Some(w.counter)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(ws[0].call(|w| w.counter).unwrap(), 0);
    }

    #[test]
    fn gather_sync_barrier_rounds() {
        let ws = workers(3);
        let mut it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap(), vec![1, 1, 1]);
        // Barrier: all shards advanced exactly once; messages sent now
        // are ordered before round 2's fetches.
        for w in &ws {
            w.cast(|w| w.weights = 7.0);
        }
        let round2 = ParIter::from_actors(ws.clone(), |w| Some(w.weights))
            .gather_sync()
            .next()
            .unwrap();
        assert_eq!(round2, vec![7.0, 7.0, 7.0]);
        assert_eq!(it.next().unwrap(), vec![2, 2, 2]);
    }

    #[test]
    fn gather_sync_ends_when_shard_exhausts() {
        let ws = workers(2);
        let mut it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.id == 1 && w.counter > 2 {
                None
            } else {
                Some(w.counter)
            }
        })
        .gather_sync();
        assert!(it.next().is_some());
        assert!(it.next().is_some());
        assert!(it.next().is_none());
    }

    #[test]
    fn gather_async_yields_all_items_any_order() {
        let ws = workers(4);
        let it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.counter > 3 {
                None
            } else {
                Some((w.id, w.counter))
            }
        })
        .gather_async(1);
        let mut got = it.collect();
        assert_eq!(got.len(), 12);
        got.sort();
        let expected: Vec<(usize, i32)> =
            (0..4).flat_map(|id| (1..=3).map(move |c| (id, c))).collect();
        assert_eq!(got, expected);
    }

    #[test]
    fn gather_async_pipelines_num_async() {
        // With num_async=2, two calls are primed per shard: after the
        // driver pulls 1 item, the actor has already computed (or is
        // computing) the second.
        let ws = workers(1);
        let mut it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            Some(w.counter)
        })
        .gather_async(2);
        assert_eq!(it.next(), Some(1));
        std::thread::sleep(std::time::Duration::from_millis(30));
        let counter = ws[0].call(|w| w.counter).unwrap();
        assert!(counter >= 2, "pipelining should prefetch, counter={counter}");
    }

    #[test]
    fn gather_async_multiple_inflight_interleaves_shards() {
        let ws = workers(3);
        let it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.counter > 10 {
                None
            } else {
                Some(w.id)
            }
        })
        .gather_async(4);
        let got = it.collect();
        assert_eq!(got.len(), 30);
        for id in 0..3 {
            assert_eq!(got.iter().filter(|&&x| x == id).count(), 10);
        }
    }

    #[test]
    fn zip_with_source_actor_pairs_handles() {
        let ws = workers(2);
        let mut it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.counter > 1 {
                None
            } else {
                Some(w.id)
            }
        })
        .gather_async_with_source(1);
        let mut pairs = vec![];
        while let Some((id, handle)) = it.next() {
            // The paired handle must address the producing actor.
            let actor_id = handle.call(|w| w.id).unwrap();
            pairs.push((id, actor_id));
        }
        assert_eq!(pairs.len(), 2);
        assert!(pairs.iter().all(|(a, b)| a == b));
    }

    // -----------------------------------------------------------------
    // Supervision: shard death mid-stream
    // -----------------------------------------------------------------

    #[test]
    fn gather_async_survives_a_dying_shard() {
        let ws = workers(3);
        let it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            if w.id == 1 && w.counter == 2 {
                panic!("shard 1 exploded");
            }
            if w.counter > 5 {
                None
            } else {
                Some(w.id)
            }
        })
        .gather_async(1);
        let got = it.collect();
        // Shards 0 and 2 deliver all 5 items; shard 1 dies after 1.
        assert_eq!(got.iter().filter(|&&x| x == 0).count(), 5);
        assert_eq!(got.iter().filter(|&&x| x == 2).count(), 5);
        assert!(got.iter().filter(|&&x| x == 1).count() <= 1);
        assert!(ws[1].await_poisoned(std::time::Duration::from_secs(2)));
        assert!(!ws[0].is_poisoned());
    }

    #[test]
    fn gather_sync_drops_dead_shard_and_continues() {
        let ws = workers(3);
        let mut it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            if w.id == 2 && w.counter == 2 {
                panic!("shard 2 exploded");
            }
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap(), vec![1, 1, 1]);
        // Round 2: shard 2 dies; the barrier completes off survivors.
        assert_eq!(it.next().unwrap(), vec![2, 2]);
        assert_eq!(it.next().unwrap(), vec![3, 3]);
        assert!(ws[2].await_poisoned(std::time::Duration::from_secs(2)));
    }
}
