//! `ParIter<W, T>` — the parallel iterator (`ParIter[T]`), sharded over a
//! set of actors of state type `W`.
//!
//! A `ParIter` is a *plan*: a [`ShardRegistry`] of shard actors plus one
//! composed closure that, when invoked **on the actor**, produces the
//! next item.  `for_each` extends the plan (still on-actor); the
//! `gather_*` sequencing operators are the only places execution is
//! driven.
//!
//! Both gather modes ride one shared bounded [`CompletionQueue`] (the
//! batched-`ray.wait` analog): shards deliver results into it with
//! `call_into`, and its bound — `shards x num_async` for `gather_async`,
//! `shards` for `gather_sync` — is exactly the in-flight budget, so
//! `num_async` is a real flow-control knob, not a hint.
//!
//! **Elasticity.** Gathers do not capture handles at plan-build time:
//! every dispatch resolves shard index -> handle through the registry.
//! A shard whose actor dies (panics) delivers a death notice instead of
//! a value; the gather parks the shard and keeps streaming off the
//! survivors — and if the owner publishes a replacement
//! (`WorkerSet::restart_dead` -> `ShardRegistry::publish`), the
//! *running* gather adopts it on its next dispatch, no plan rebuild.
//! Completion tags encode `(shard, epoch)` so late completions of a
//! dead incarnation — above all its death notices — are discarded
//! instead of being attributed to (and retiring) the replacement.
//!
//! **Scale-out.** Membership itself is dynamic: gathers rescan the
//! registry whenever its publish counter moves, so shards appended by
//! `ShardRegistry::grow` (-> `WorkerSet::scale_to`/`add_worker`) join a
//! *running* stream — `gather_async` primes a fresh `num_async` credit
//! pipeline for each new index mid-stream (growing the shared
//! completion queue's bound to match), while `gather_sync` admits new
//! shards only at round boundaries (a barrier round's membership is
//! frozen at dispatch).  Shards tombstoned by `ShardRegistry::retire`
//! (-> `WorkerSet::remove_worker`) stop being dispatched to and their
//! in-flight completions drain through the same epoch/mode discard
//! machinery that handles a dead incarnation's; a later publish into
//! the slot rejoins it.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::actor::tags::{decode_tag, encode_tag, MAX_SHARDS};
use crate::actor::{
    ActorHandle, Completion, CompletionQueue, FaultCounters, ShardRegistry,
};

use super::LocalIter;

type PlanFn<W, T> = Arc<dyn Fn(&mut W) -> Option<T> + Send + Sync>;

/// Deadline supervision for the gathers: a per-dispatch liveness bound.
///
/// A completion queue pop can park forever behind a *wedged* shard — an
/// actor that neither answers nor dies, so its `call_into` guard never
/// fires.  With supervision attached
/// ([`ParIter::gather_async_deadline`] /
/// [`ParIter::gather_sync_deadline`]), a shard whose in-flight
/// completions have all been silent for `deadline` is declared
/// **suspect**: its outstanding completions are written off the
/// gather's ledger (and remembered per epoch, so the corpse's late
/// completions are discarded against the write-off instead of
/// corrupting the exactly-one-completion accounting), the incarnation
/// is force-poisoned via [`ActorHandle::kill`], and the shard parks as
/// dead — rejoining when the owner publishes a replacement, exactly
/// like a shard that crashed honestly.  Streams therefore degrade to
/// the surviving quorum instead of hanging the whole plan.
///
/// A slow-but-healthy shard written off by a too-tight deadline is a
/// tolerable false positive: it is killed (so it cannot complete twice)
/// and the owner's restart policy brings up a replacement.
#[derive(Clone)]
pub struct DeadlineSupervision {
    /// Maximum silence tolerated per shard while it has completions in
    /// flight; the clock rearms on every dispatch to the shard.
    pub deadline: Duration,
    /// Shared fault ledger suspects are reported into.  Share the
    /// owning `WorkerSet`'s counters (via
    /// [`DeadlineSupervision::with_counters`]) so suspects, forced
    /// restarts, and breaker trips land in one snapshot.
    pub counters: Arc<FaultCounters>,
}

impl DeadlineSupervision {
    /// Supervision with a fresh ledger.
    pub fn new(deadline: Duration) -> Self {
        DeadlineSupervision {
            deadline,
            counters: Arc::new(FaultCounters::default()),
        }
    }

    /// Supervision reporting into an existing ledger.
    pub fn with_counters(
        deadline: Duration,
        counters: Arc<FaultCounters>,
    ) -> Self {
        DeadlineSupervision { deadline, counters }
    }
}

/// Per-shard gather state: streaming, cleanly finished, dead, or
/// tombstoned — the latter two rejoin when the registry publishes a
/// newer epoch into the slot.
#[derive(Clone, Copy, PartialEq, Eq)]
enum ShardMode {
    Active,
    /// The plan returned `None` on this shard — a terminal condition
    /// (restarting the *actor* does not restart an exhausted stream).
    Exhausted,
    /// The current incarnation died; the shard rejoins if a newer epoch
    /// is published.
    Dead,
    /// The slot was tombstoned (`ShardRegistry::retire`): no further
    /// dispatches, in-flight completions are drained and discarded by
    /// epoch/mode, and a later publish (epoch bump) rejoins the shard.
    Retired,
}

pub struct ParIter<W: 'static, T> {
    registry: ShardRegistry<W>,
    plan: PlanFn<W, T>,
}

impl<W: 'static, T: Send + 'static> Clone for ParIter<W, T> {
    fn clone(&self) -> Self {
        ParIter { registry: self.registry.clone(), plan: self.plan.clone() }
    }
}

impl<W: 'static, T: Send + 'static> ParIter<W, T> {
    /// Create a parallel iterator from a fixed set of source actors and
    /// a source function (e.g. "sample a batch from this rollout
    /// worker").  Returning `None` ends that shard.  The actors are
    /// wrapped in a private single-incarnation registry; use
    /// [`ParIter::from_registry`] to share an elastic one.
    pub fn from_actors(
        shards: Vec<ActorHandle<W>>,
        source: impl Fn(&mut W) -> Option<T> + Send + Sync + 'static,
    ) -> Self {
        Self::from_registry(ShardRegistry::new(shards), source)
    }

    /// Create a parallel iterator over a shared [`ShardRegistry`]: the
    /// owner of the registry (e.g. a `WorkerSet`) can publish
    /// replacement actors and running gathers built from this plan will
    /// adopt them live.
    pub fn from_registry(
        registry: ShardRegistry<W>,
        source: impl Fn(&mut W) -> Option<T> + Send + Sync + 'static,
    ) -> Self {
        assert!(!registry.is_empty(), "ParIter needs at least one shard");
        assert!(
            registry.len() <= MAX_SHARDS,
            "shard index must fit the tag encoding"
        );
        ParIter { registry, plan: Arc::new(source) }
    }

    pub fn num_shards(&self) -> usize {
        self.registry.len()
    }

    /// The registry behind this plan (current incarnations).
    pub fn registry(&self) -> &ShardRegistry<W> {
        &self.registry
    }

    /// Parallel transformation, scheduled **onto the source actor** so
    /// the op can read/write worker-local state (paper §4
    /// Transformation; `ComputeGradients` relies on this locality).
    pub fn for_each<U: Send + 'static>(
        self,
        op: impl Fn(&mut W, T) -> U + Send + Sync + 'static,
    ) -> ParIter<W, U> {
        let plan = self.plan;
        ParIter {
            registry: self.registry,
            plan: Arc::new(move |w| plan(w).map(|t| op(w, t))),
        }
    }

    /// Sequencing operator, async mode (pink arrow): items are merged
    /// into the sequential iterator *as soon as they are ready*, in
    /// nondeterministic order.  `num_async` requests are kept in flight
    /// per shard (the pipeline-parallelism knob, paper §3) via the
    /// shared completion queue.
    pub fn gather_async(self, num_async: usize) -> LocalIter<T> {
        self.gather_async_with_source(num_async).for_each(|(t, _)| t)
    }

    /// [`ParIter::gather_async`] under [`DeadlineSupervision`]: a shard
    /// whose in-flight completions go silent past the deadline is
    /// written off (suspect), force-poisoned, and the stream keeps
    /// flowing off the surviving quorum — a wedged actor can no longer
    /// park the consumer forever.
    pub fn gather_async_deadline(
        self,
        num_async: usize,
        sup: DeadlineSupervision,
    ) -> LocalIter<T> {
        self.gather_async_opts(num_async, Some(sup)).for_each(|(t, _)| t)
    }

    /// `gather_async` + `zip_with_source_actor`: each item is paired
    /// with the handle of the shard actor that produced it (used by
    /// Ape-X's `UpdateWorkerWeights` to message the producing worker).
    /// With an elastic registry the paired handle is always the live
    /// incarnation — items of a replaced incarnation are discarded, so
    /// a weight push can never target a corpse.
    pub fn gather_async_with_source(
        self,
        num_async: usize,
    ) -> LocalIter<(T, ActorHandle<W>)> {
        self.gather_async_opts(num_async, None)
    }

    /// [`ParIter::gather_async_with_source`] under
    /// [`DeadlineSupervision`] — see [`ParIter::gather_async_deadline`].
    pub fn gather_async_with_source_deadline(
        self,
        num_async: usize,
        sup: DeadlineSupervision,
    ) -> LocalIter<(T, ActorHandle<W>)> {
        self.gather_async_opts(num_async, Some(sup))
    }

    fn gather_async_opts(
        self,
        num_async: usize,
        sup: Option<DeadlineSupervision>,
    ) -> LocalIter<(T, ActorHandle<W>)> {
        assert!(num_async >= 1);
        struct State<W: 'static, T: Send + 'static> {
            registry: ShardRegistry<W>,
            plan: PlanFn<W, T>,
            queue: CompletionQueue<Option<T>>,
            /// Completions still expected, across *all* epochs.
            outstanding: usize,
            mode: Vec<ShardMode>,
            /// Epoch each shard's current submissions carry.
            epoch: Vec<u64>,
            /// Per-shard completions still expected (any epoch) — the
            /// drain gauge behind capacity reclaim.
            inflight: Vec<usize>,
            /// True while the shard's `num_async` slice of the queue
            /// bound is held.  Granted on (re)prime, released once a
            /// tombstoned shard's last in-flight completion drains —
            /// without the release, repeated grow/retire cycles would
            /// inflate the bound without limit.
            cap_held: Vec<bool>,
            /// Registry version last scanned for replacements.
            reg_version: u64,
            /// Deadline supervision, if attached.
            sup: Option<DeadlineSupervision>,
            /// Per shard: instant of the last dispatch to it — the
            /// liveness clock deadline supervision reads.
            last_activity: Vec<Instant>,
            /// Per shard, per epoch: completions written off by
            /// deadline supervision that have not yet surfaced.  A
            /// completion matching an entry was already deducted from
            /// `outstanding`/`inflight` at write-off time and is
            /// discarded against the entry instead of being accounted
            /// twice.
            forgiven: Vec<HashMap<u64, usize>>,
            started: bool,
            /// Set once the stream has returned `None`: end-of-stream
            /// is terminal — a later publish must not resurrect a
            /// finished iterator (matching the Exhausted contract).
            finished: bool,
        }
        impl<W: 'static, T: Send + 'static> State<W, T> {
            /// Submit one plan invocation to a pre-resolved incarnation
            /// of shard `idx`.  Every submission yields exactly one
            /// completion (value or death notice), so `outstanding` can
            /// never leak.
            fn submit_to(&mut self, idx: usize, handle: &ActorHandle<W>, ep: u64) {
                self.epoch[idx] = ep;
                self.last_activity[idx] = Instant::now();
                let plan = self.plan.clone();
                handle.call_into(
                    encode_tag(idx, ep),
                    &self.queue,
                    move |w| plan(w),
                );
                self.outstanding += 1;
                self.inflight[idx] += 1;
            }

            /// [`Self::submit_to`] the registry's current incarnation.
            /// `false` (nothing submitted, shard parked as retired) if
            /// the slot was tombstoned since the caller looked.
            fn submit(&mut self, idx: usize, num_async: usize) -> bool {
                match self.registry.get_live(idx) {
                    Some((handle, ep)) => {
                        self.submit_to(idx, &handle, ep);
                        true
                    }
                    None => {
                        self.mode[idx] = ShardMode::Retired;
                        self.maybe_release(idx, num_async);
                        false
                    }
                }
            }

            /// Start (or restart) streaming shard `idx`: mark it
            /// active, re-grant its slice of the queue bound if it was
            /// reclaimed, and prime its full `num_async` pipeline.
            fn prime(&mut self, idx: usize, num_async: usize) {
                self.mode[idx] = ShardMode::Active;
                if !self.cap_held[idx] {
                    self.cap_held[idx] = true;
                    self.queue.add_capacity(num_async);
                }
                for _ in 0..num_async {
                    if !self.submit(idx, num_async) {
                        break;
                    }
                }
            }

            /// Release a tombstoned shard's slice of the queue bound
            /// once its last in-flight completion has drained (a later
            /// `prime` re-grants it).
            fn maybe_release(&mut self, idx: usize, num_async: usize) {
                if self.mode[idx] == ShardMode::Retired
                    && self.inflight[idx] == 0
                    && self.cap_held[idx]
                {
                    self.cap_held[idx] = false;
                    self.queue.remove_capacity(num_async);
                }
            }

            /// Reconcile with the registry when its publish counter
            /// moved (cheap: one atomic load per pass otherwise):
            /// tombstoned slots stop streaming, dead/retired slots with
            /// a newer published epoch rejoin, and indices appended by
            /// `grow` are admitted mid-stream with a full credit
            /// pipeline (the queue bound grows to match).
            fn sync_membership(&mut self, num_async: usize) {
                let v = self.registry.version();
                if v == self.reg_version {
                    return;
                }
                self.reg_version = v;
                for idx in 0..self.mode.len() {
                    match self.mode[idx] {
                        ShardMode::Active => {
                            if self.registry.is_retired(idx) {
                                self.mode[idx] = ShardMode::Retired;
                                self.maybe_release(idx, num_async);
                            }
                        }
                        ShardMode::Dead | ShardMode::Retired => {
                            if self.registry.epoch(idx) > self.epoch[idx] {
                                self.prime(idx, num_async);
                            } else if self.mode[idx] == ShardMode::Dead
                                && self.registry.is_retired(idx)
                            {
                                // A dead shard tombstoned afterwards:
                                // it will never be restarted in place,
                                // so its budget is reclaimable too.
                                self.mode[idx] = ShardMode::Retired;
                                self.maybe_release(idx, num_async);
                            }
                        }
                        ShardMode::Exhausted => {}
                    }
                }
                let reg_len = self.registry.len();
                while self.mode.len() < reg_len {
                    let idx = self.mode.len();
                    self.mode.push(ShardMode::Dead); // prime() activates
                    self.epoch.push(0);
                    self.inflight.push(0);
                    self.cap_held.push(false); // prime() grants the slice
                    self.last_activity.push(Instant::now());
                    self.forgiven.push(HashMap::new());
                    self.prime(idx, num_async);
                }
            }

            /// Time until the soonest per-shard deadline among shards
            /// with completions in flight (zero if one is already
            /// overdue; `deadline` if, impossibly, none is in flight).
            fn next_deadline_wait(&self, deadline: Duration) -> Duration {
                let now = Instant::now();
                let mut wait = deadline;
                for idx in 0..self.mode.len() {
                    if self.inflight[idx] == 0 {
                        continue;
                    }
                    let due = self.last_activity[idx] + deadline;
                    wait = wait.min(due.saturating_duration_since(now));
                }
                wait
            }

            /// Declare every shard silent past the deadline *suspect*:
            /// write its in-flight completions off the ledger
            /// (remembered per epoch in `forgiven` so the late
            /// completions are discarded when they finally surface),
            /// force-poison the incarnation the gather dispatched to,
            /// and park the shard as dead — a published replacement
            /// rejoins it exactly like after an honest crash.
            fn write_off_overdue(
                &mut self,
                sup: &DeadlineSupervision,
                num_async: usize,
            ) {
                let now = Instant::now();
                for idx in 0..self.mode.len() {
                    if self.inflight[idx] == 0
                        || now.duration_since(self.last_activity[idx])
                            < sup.deadline
                    {
                        continue;
                    }
                    sup.counters.note_suspect();
                    let ep = self.epoch[idx];
                    *self.forgiven[idx].entry(ep).or_insert(0) +=
                        self.inflight[idx];
                    self.outstanding -= self.inflight[idx];
                    self.inflight[idx] = 0;
                    if self.mode[idx] == ShardMode::Active {
                        // Kill only the incarnation we dispatched to:
                        // if the registry already holds a replacement,
                        // the corpse is the owner's to reap.
                        if let Some((handle, ep_now)) =
                            self.registry.get_live(idx)
                        {
                            if ep_now == ep {
                                handle.kill();
                            }
                        }
                        self.mode[idx] = ShardMode::Dead;
                    }
                    self.maybe_release(idx, num_async);
                }
            }
        }
        // Version BEFORE len: a grow landing between the two reads is
        // then either covered by `mode` (len already included it) or by
        // the first `sync_membership` rescan (version read is older
        // than the grow's bump).  The reverse order could cache a
        // version that already covers a shard `mode` missed.
        let reg_version = self.registry.version();
        let n = self.registry.len();
        let mut st = State {
            queue: CompletionQueue::bounded((n * num_async).max(1)),
            reg_version,
            registry: self.registry,
            plan: self.plan,
            outstanding: 0,
            mode: vec![ShardMode::Active; n],
            epoch: vec![0; n],
            inflight: vec![0; n],
            // The initial bound already covers the starting shards.
            cap_held: vec![true; n],
            sup,
            last_activity: vec![Instant::now(); n],
            forgiven: vec![HashMap::new(); n],
            started: false,
            finished: false,
        };
        LocalIter::from_fn(move || {
            if st.finished {
                return None;
            }
            if !st.started {
                st.started = true;
                // Prime the pipeline: num_async calls in flight per shard.
                for i in 0..n {
                    st.prime(i, num_async);
                }
            }
            loop {
                st.sync_membership(num_async);
                if st.outstanding == 0 {
                    // Every submission resolved and no shard is active:
                    // the stream ends (dead shards with no published
                    // replacement do not block it — same semantics as
                    // the pre-registry gather), and stays ended.
                    st.finished = true;
                    return None;
                }
                let completion = match st.sup.clone() {
                    None => st.queue.pop(),
                    Some(sup) => {
                        let wait = st.next_deadline_wait(sup.deadline);
                        match st.queue.pop_timeout(wait) {
                            Some(c) => c,
                            None => {
                                // Nothing surfaced before the soonest
                                // deadline: write off the overdue
                                // shard(s) and re-enter the loop (the
                                // membership scan may rejoin a
                                // replacement; `outstanding == 0` ends
                                // the stream if nothing survived).
                                st.write_off_overdue(&sup, num_async);
                                continue;
                            }
                        }
                    }
                };
                let (idx, ep) = decode_tag(completion.tag());
                if let Some(cnt) = st.forgiven[idx].get_mut(&ep) {
                    // A written-off shard's completion finally
                    // surfaced.  It was deducted from the ledger at
                    // write-off time: consume the forgiveness credit
                    // and discard, touching neither `outstanding` nor
                    // `inflight`.
                    *cnt -= 1;
                    if *cnt == 0 {
                        st.forgiven[idx].remove(&ep);
                    }
                    continue;
                }
                st.outstanding -= 1;
                st.inflight[idx] -= 1;
                let current =
                    ep == st.epoch[idx] && st.mode[idx] == ShardMode::Active;
                match completion {
                    Completion::Item { value: Some(t), .. } if current => {
                        // One registry resolution serves the staleness
                        // check, the refill, and the paired handle.
                        match st.registry.get_live(idx) {
                            None => {
                                // The slot was tombstoned while this
                                // item sat in the queue: drain-discard
                                // (no refill, nothing to pair with).
                                st.mode[idx] = ShardMode::Retired;
                            }
                            Some((_, ep_now)) if ep_now > st.epoch[idx] => {
                                // The producer was replaced while this
                                // item sat in the queue (publish raced
                                // ahead of the death notices): discard
                                // the corpse's item and adopt the
                                // replacement at full pipeline depth —
                                // the pending stale notices re-prime
                                // nothing.
                                st.prime(idx, num_async);
                            }
                            Some((handle, ep_now)) => {
                                // Refill the shard's pipeline slot and
                                // pair the item with its (live)
                                // producer.
                                st.submit_to(idx, &handle, ep_now);
                                return Some((t, handle));
                            }
                        }
                    }
                    Completion::Item { value: Some(_), .. } => {
                        // Late result from a pipelined call issued
                        // before the shard exhausted, died, was
                        // replaced, or was tombstoned: drop it.
                    }
                    Completion::Item { value: None, .. } => {
                        if current {
                            st.mode[idx] = ShardMode::Exhausted;
                        }
                    }
                    Completion::Dropped { .. } => {
                        if current {
                            // The incarnation we were streaming died.
                            // If a replacement is already published,
                            // adopt it now; otherwise park the shard —
                            // `sync_membership` rejoins it when the
                            // owner publishes.  A stale notice (ep <
                            // epoch, e.g. the 2nd..num_async-th notice
                            // of an incarnation we already replaced)
                            // falls through and must NOT retire the
                            // fresh incarnation.
                            if st.registry.epoch(idx) > st.epoch[idx] {
                                st.prime(idx, num_async);
                            } else if st.registry.is_retired(idx) {
                                st.mode[idx] = ShardMode::Retired;
                            } else {
                                st.mode[idx] = ShardMode::Dead;
                            }
                        }
                    }
                }
                // Every completion path above may have been shard
                // `idx`'s last in-flight one: reclaim its slice of the
                // queue bound if it is tombstoned and drained.
                st.maybe_release(idx, num_async);
            }
        })
    }

    /// Sequencing operator, sync mode (black arrow): each `next()`
    /// issues one call to **every** live shard, waits for all of them
    /// (executing in parallel across actor threads), and yields the
    /// round as a `Vec` in shard order.  Upstream is fully halted
    /// between fetches — barrier semantics, so actor messages sent
    /// between fetches (e.g. a weight broadcast) are ordered with
    /// respect to dataflow steps (paper §4 Sequencing).  Ends when any
    /// shard is exhausted; a shard whose actor *died* is dropped from
    /// subsequent rounds — and rejoins at the next round boundary once
    /// a replacement is published (mid-round, if the death notice
    /// arrives while the barrier is still collecting).
    ///
    /// Membership changes are admitted **only at round boundaries**:
    /// shards appended by `grow` mid-round join the *next* round (a
    /// barrier round's membership is frozen at dispatch, so round
    /// vectors stay coherent), and tombstoned shards stop being
    /// dispatched from the next boundary on.
    pub fn gather_sync(self) -> LocalIter<Vec<T>> {
        self.gather_sync_opts(None)
    }

    /// [`ParIter::gather_sync`] under [`DeadlineSupervision`]: a
    /// barrier round stops waiting on a shard whose call has been
    /// silent past the deadline — the shard is written off (suspect),
    /// force-poisoned, and the round completes off the surviving
    /// quorum.  It rejoins at a later round boundary once the owner
    /// publishes a replacement.
    pub fn gather_sync_deadline(
        self,
        sup: DeadlineSupervision,
    ) -> LocalIter<Vec<T>> {
        self.gather_sync_opts(Some(sup))
    }

    fn gather_sync_opts(
        self,
        sup: Option<DeadlineSupervision>,
    ) -> LocalIter<Vec<T>> {
        let registry = self.registry;
        let plan = self.plan;
        let queue: CompletionQueue<Option<T>> =
            CompletionQueue::bounded(registry.len().max(1));
        let mut mode = vec![ShardMode::Active; registry.len()];
        let mut epoch = vec![0u64; mode.len()];
        // Submissions written off by deadline supervision, keyed by
        // (shard, epoch): the corpse's completion may surface rounds
        // later and must be discarded against this ledger instead of
        // being counted toward whichever round is then collecting.
        let mut forgiven: HashMap<(usize, u64), usize> = HashMap::new();
        // One queue slot held per admitted shard; a tombstoned shard's
        // slot is reclaimed at the next round boundary (rounds drain
        // fully, so nothing of its can be in flight there) and
        // re-granted if the slot is revived — grow/retire cycles do not
        // inflate the round bound without limit.
        let mut cap_held = vec![true; mode.len()];
        let mut done = false;
        LocalIter::from_fn(move || {
            if done {
                return None;
            }
            // Round boundary — the sole membership admission point:
            // append shards grown since the last round, tombstone
            // retired ones, rejoin dead/retired slots republished
            // since they left.
            while mode.len() < registry.len() {
                mode.push(ShardMode::Active);
                epoch.push(0);
                cap_held.push(false); // granted below
            }
            for i in 0..mode.len() {
                match mode[i] {
                    ShardMode::Active => {
                        if registry.is_retired(i) {
                            mode[i] = ShardMode::Retired;
                        }
                    }
                    ShardMode::Dead | ShardMode::Retired => {
                        if registry.epoch(i) > epoch[i] {
                            mode[i] = ShardMode::Active;
                        } else if mode[i] == ShardMode::Dead
                            && registry.is_retired(i)
                        {
                            // Dead-then-tombstoned: reclaimable below.
                            mode[i] = ShardMode::Retired;
                        }
                    }
                    ShardMode::Exhausted => {}
                }
            }
            for i in 0..mode.len() {
                match mode[i] {
                    ShardMode::Active if !cap_held[i] => {
                        cap_held[i] = true;
                        queue.add_capacity(1);
                    }
                    ShardMode::Retired if cap_held[i] => {
                        cap_held[i] = false;
                        queue.remove_capacity(1);
                    }
                    // Dead shards keep their slot: they may be
                    // republished, and their budget is already idle.
                    _ => {}
                }
            }
            let n = mode.len();
            let mut expected = 0usize;
            // Per-shard dispatch clocks for deadline supervision: a
            // round's membership is frozen here, so one issue instant
            // per admitted shard is the whole liveness state.
            let mut pending = vec![false; n];
            let mut issued_at = vec![Instant::now(); n];
            for i in 0..n {
                if mode[i] == ShardMode::Active {
                    match registry.get_live(i) {
                        Some((handle, ep)) => {
                            epoch[i] = ep;
                            let plan = plan.clone();
                            handle.call_into(
                                encode_tag(i, ep),
                                &queue,
                                move |w| plan(w),
                            );
                            pending[i] = true;
                            issued_at[i] = Instant::now();
                            expected += 1;
                        }
                        None => mode[i] = ShardMode::Retired,
                    }
                }
            }
            if expected == 0 {
                done = true;
                return None;
            }
            // Collect the whole round (reassembled into shard order so
            // barrier plans stay deterministic) before deciding.
            let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
            while expected > 0 {
                let completion = match &sup {
                    None => queue.pop(),
                    Some(s) => {
                        let now = Instant::now();
                        let mut wait = s.deadline;
                        for i in 0..n {
                            if pending[i] {
                                let due = issued_at[i] + s.deadline;
                                wait = wait
                                    .min(due.saturating_duration_since(now));
                            }
                        }
                        match queue.pop_timeout(wait) {
                            Some(c) => c,
                            None => {
                                // The barrier stops waiting on overdue
                                // shards: write them off, force-poison
                                // the incarnation dispatched to, and
                                // complete the round off the surviving
                                // quorum.  A replacement rejoins at a
                                // later round boundary.
                                let now = Instant::now();
                                for i in 0..n {
                                    if !pending[i]
                                        || now.duration_since(issued_at[i])
                                            < s.deadline
                                    {
                                        continue;
                                    }
                                    s.counters.note_suspect();
                                    *forgiven
                                        .entry((i, epoch[i]))
                                        .or_insert(0) += 1;
                                    pending[i] = false;
                                    expected -= 1;
                                    match registry.get_live(i) {
                                        Some((handle, ep_now)) => {
                                            if ep_now == epoch[i] {
                                                handle.kill();
                                            }
                                            mode[i] = ShardMode::Dead;
                                        }
                                        None => {
                                            mode[i] = ShardMode::Retired;
                                        }
                                    }
                                }
                                continue;
                            }
                        }
                    }
                };
                let (i, ep) = decode_tag(completion.tag());
                if let Some(cnt) = forgiven.get_mut(&(i, ep)) {
                    // A written-off submission's completion surfaced
                    // (possibly rounds later): it is already off the
                    // round ledger — consume the forgiveness credit
                    // and discard.
                    *cnt -= 1;
                    if *cnt == 0 {
                        forgiven.remove(&(i, ep));
                    }
                    continue;
                }
                expected -= 1;
                match completion {
                    Completion::Item { value: Some(t), .. } => {
                        if ep == epoch[i] {
                            slots[i] = Some(t);
                            pending[i] = false;
                        }
                    }
                    Completion::Item { value: None, .. } => {
                        done = true;
                        if ep == epoch[i] {
                            pending[i] = false;
                        }
                    }
                    Completion::Dropped { .. } => {
                        if ep == epoch[i] {
                            pending[i] = false;
                            // This round's submission died.  If a
                            // replacement is already live, re-issue the
                            // call so the barrier completes with the
                            // replacement's item; else drop the shard
                            // from this and subsequent rounds (as
                            // retired if it was tombstoned mid-round).
                            match registry.get_live(i) {
                                Some((handle, ep2)) if ep2 > ep => {
                                    epoch[i] = ep2;
                                    let plan = plan.clone();
                                    handle.call_into(
                                        encode_tag(i, ep2),
                                        &queue,
                                        move |w| plan(w),
                                    );
                                    pending[i] = true;
                                    issued_at[i] = Instant::now();
                                    expected += 1;
                                }
                                Some(_) => mode[i] = ShardMode::Dead,
                                None => mode[i] = ShardMode::Retired,
                            }
                        }
                    }
                }
            }
            if done {
                return None;
            }
            let round: Vec<T> = slots.into_iter().flatten().collect();
            if round.is_empty() {
                done = true;
                return None;
            }
            Some(round)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::spawn_group;

    struct Worker {
        id: usize,
        counter: i32,
        weights: f32,
    }

    fn workers(n: usize) -> Vec<ActorHandle<Worker>> {
        spawn_group("w", n, |i| {
            Box::new(move || Worker { id: i, counter: 0, weights: 0.0 })
        })
    }

    #[test]
    fn tag_roundtrip() {
        for (idx, ep) in [(0usize, 0u64), (17, 3), (MAX_SHARDS - 1, 1 << 40)] {
            assert_eq!(decode_tag(encode_tag(idx, ep)), (idx, ep));
        }
    }

    #[test]
    fn for_each_runs_on_source_actor() {
        let ws = workers(2);
        let it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            Some(w.counter)
        })
        // The op reads actor-local state (w.id): proves on-actor exec.
        .for_each(|w, c| (w.id, c));
        let mut gathered = it.gather_sync();
        let round = gathered.next().unwrap();
        let mut ids: Vec<usize> = round.iter().map(|(id, _)| *id).collect();
        ids.sort();
        assert_eq!(ids, vec![0, 1]);
        assert!(round.iter().all(|(_, c)| *c == 1));
    }

    #[test]
    fn plan_is_lazy_until_gathered() {
        let ws = workers(1);
        let _plan = ParIter::from_actors(ws.clone(), |w: &mut Worker| {
            w.counter += 1;
            Some(w.counter)
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(ws[0].call(|w| w.counter).unwrap(), 0);
    }

    #[test]
    fn gather_sync_barrier_rounds() {
        let ws = workers(3);
        let mut it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap(), vec![1, 1, 1]);
        // Barrier: all shards advanced exactly once; messages sent now
        // are ordered before round 2's fetches.
        for w in &ws {
            w.cast(|w| w.weights = 7.0);
        }
        let round2 = ParIter::from_actors(ws.clone(), |w| Some(w.weights))
            .gather_sync()
            .next()
            .unwrap();
        assert_eq!(round2, vec![7.0, 7.0, 7.0]);
        assert_eq!(it.next().unwrap(), vec![2, 2, 2]);
    }

    #[test]
    fn gather_sync_ends_when_shard_exhausts() {
        let ws = workers(2);
        let mut it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.id == 1 && w.counter > 2 {
                None
            } else {
                Some(w.counter)
            }
        })
        .gather_sync();
        assert!(it.next().is_some());
        assert!(it.next().is_some());
        assert!(it.next().is_none());
    }

    #[test]
    fn gather_async_yields_all_items_any_order() {
        let ws = workers(4);
        let it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.counter > 3 {
                None
            } else {
                Some((w.id, w.counter))
            }
        })
        .gather_async(1);
        let mut got = it.collect();
        assert_eq!(got.len(), 12);
        got.sort();
        let expected: Vec<(usize, i32)> =
            (0..4).flat_map(|id| (1..=3).map(move |c| (id, c))).collect();
        assert_eq!(got, expected);
    }

    #[test]
    fn gather_async_pipelines_num_async() {
        // With num_async=2, two calls are primed per shard: after the
        // driver pulls 1 item, the actor has already computed (or is
        // computing) the second.
        let ws = workers(1);
        let mut it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            Some(w.counter)
        })
        .gather_async(2);
        assert_eq!(it.next(), Some(1));
        std::thread::sleep(std::time::Duration::from_millis(30));
        let counter = ws[0].call(|w| w.counter).unwrap();
        assert!(counter >= 2, "pipelining should prefetch, counter={counter}");
    }

    #[test]
    fn gather_async_multiple_inflight_interleaves_shards() {
        let ws = workers(3);
        let it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.counter > 10 {
                None
            } else {
                Some(w.id)
            }
        })
        .gather_async(4);
        let got = it.collect();
        assert_eq!(got.len(), 30);
        for id in 0..3 {
            assert_eq!(got.iter().filter(|&&x| x == id).count(), 10);
        }
    }

    #[test]
    fn zip_with_source_actor_pairs_handles() {
        let ws = workers(2);
        let mut it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            if w.counter > 1 {
                None
            } else {
                Some(w.id)
            }
        })
        .gather_async_with_source(1);
        let mut pairs = vec![];
        while let Some((id, handle)) = it.next() {
            // The paired handle must address the producing actor.
            let actor_id = handle.call(|w| w.id).unwrap();
            pairs.push((id, actor_id));
        }
        assert_eq!(pairs.len(), 2);
        assert!(pairs.iter().all(|(a, b)| a == b));
    }

    // -----------------------------------------------------------------
    // Supervision: shard death mid-stream
    // -----------------------------------------------------------------

    #[test]
    fn gather_async_survives_a_dying_shard() {
        let ws = workers(3);
        let it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            if w.id == 1 && w.counter == 2 {
                panic!("shard 1 exploded");
            }
            if w.counter > 5 {
                None
            } else {
                Some(w.id)
            }
        })
        .gather_async(1);
        let got = it.collect();
        // Shards 0 and 2 deliver all 5 items; shard 1 dies after 1.
        assert_eq!(got.iter().filter(|&&x| x == 0).count(), 5);
        assert_eq!(got.iter().filter(|&&x| x == 2).count(), 5);
        assert!(got.iter().filter(|&&x| x == 1).count() <= 1);
        assert!(ws[1].await_poisoned(std::time::Duration::from_secs(2)));
        assert!(!ws[0].is_poisoned());
    }

    #[test]
    fn gather_sync_drops_dead_shard_and_continues() {
        let ws = workers(3);
        let mut it = ParIter::from_actors(ws.clone(), |w| {
            w.counter += 1;
            if w.id == 2 && w.counter == 2 {
                panic!("shard 2 exploded");
            }
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap(), vec![1, 1, 1]);
        // Round 2: shard 2 dies; the barrier completes off survivors.
        assert_eq!(it.next().unwrap(), vec![2, 2]);
        assert_eq!(it.next().unwrap(), vec![3, 3]);
        assert!(ws[2].await_poisoned(std::time::Duration::from_secs(2)));
    }

    // -----------------------------------------------------------------
    // Elasticity: published replacements rejoin running gathers
    // -----------------------------------------------------------------

    fn replacement(id: usize) -> ActorHandle<Worker> {
        ActorHandle::spawn("w-replacement", move || Worker {
            id,
            counter: 1000,
            weights: 0.0,
        })
    }

    #[test]
    fn gather_async_adopts_published_replacement_live() {
        let ws = workers(2);
        let registry = ShardRegistry::new(ws.clone());
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            if w.id == 1 && w.counter == 3 {
                panic!("shard 1 exploded");
            }
            Some((w.id, w.counter))
        })
        .gather_async(1);
        // Drain until shard 1's death notice has retired it (shard 0
        // keeps streaming).
        let mut seen_shard1 = 0;
        for _ in 0..32 {
            let (id, _) = it.next().unwrap();
            if id == 1 {
                seen_shard1 += 1;
            }
        }
        assert!(seen_shard1 <= 2);
        assert!(ws[1].await_poisoned(std::time::Duration::from_secs(2)));
        // Publish a replacement into the registry: the SAME running
        // gather must start yielding its items (counter starts at 1000).
        registry.publish(1, replacement(1));
        let mut replacement_items = 0;
        for _ in 0..64 {
            let (id, c) = it.next().unwrap();
            if id == 1 {
                assert!(c > 1000, "item from the dead incarnation: {c}");
                replacement_items += 1;
            }
        }
        assert!(
            replacement_items > 0,
            "replacement never joined the running gather"
        );
    }

    #[test]
    fn gather_sync_readmits_replacement_at_round_boundary() {
        let ws = workers(2);
        let registry = ShardRegistry::new(ws.clone());
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            if w.id == 0 && w.counter == 2 {
                panic!("shard 0 exploded");
            }
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap(), vec![1, 1]);
        // Shard 0 dies in round 2; the barrier completes off shard 1.
        assert_eq!(it.next().unwrap(), vec![2]);
        assert!(ws[0].await_poisoned(std::time::Duration::from_secs(2)));
        registry.publish(0, replacement(0));
        // Round 3 includes the replacement again (counter 1001).
        assert_eq!(it.next().unwrap(), vec![1001, 3]);
        assert_eq!(it.next().unwrap(), vec![1002, 4]);
    }

    #[test]
    fn stale_death_notices_do_not_retire_the_replacement() {
        // num_async=2: the dying incarnation leaves multiple in-flight
        // submissions -> multiple death notices, all tagged with epoch
        // 0.  The replacement is published before the gather observes
        // any of them; the first notice adopts it, and every later
        // stale notice must be discarded — not counted against the
        // fresh incarnation (which a tag without the epoch would
        // wrongly retire).
        let ws = workers(1);
        let registry = ShardRegistry::new(ws.clone());
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            if w.counter >= 1000 {
                // Replacement incarnation: finite stream 1001..=1004.
                if w.counter >= 1005 {
                    return None;
                }
                return Some(w.counter);
            }
            if w.counter == 1 {
                return Some(w.counter); // first call survives
            }
            panic!("first incarnation dies on its second call");
        })
        .gather_async(2);
        // Prime the pipeline; the first call's item arrives, the second
        // call panics, and the refill lands on a dying/dead actor —
        // leaving >= 2 epoch-0 death notices queued behind the item.
        assert_eq!(it.next(), Some(1));
        assert!(ws[0].await_poisoned(std::time::Duration::from_secs(2)));
        registry.publish(0, replacement(0));
        // The epoch guard lets exactly one notice trigger adoption and
        // discards the rest; the replacement's items then flow into the
        // same gather until it exhausts cleanly.
        let got = it.collect();
        assert_eq!(got, vec![1001, 1002, 1003, 1004]);
    }

    // -----------------------------------------------------------------
    // Scale-out: grown shards join, tombstoned shards drain out
    // -----------------------------------------------------------------

    #[test]
    fn gather_async_admits_grown_shard_mid_stream() {
        let ws = workers(1);
        let registry = ShardRegistry::new(ws);
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            Some((w.id, w.counter))
        })
        .gather_async(2);
        for _ in 0..4 {
            assert_eq!(it.next().unwrap().0, 0);
        }
        // Grow while the gather is live: the new index must start
        // yielding without a plan rebuild.
        let idx = registry.grow(replacement(7)).unwrap();
        assert_eq!(idx, 1);
        let mut from_new = 0;
        for _ in 0..32 {
            let (id, c) = it.next().unwrap();
            if id == 7 {
                assert!(c > 1000, "grown shard items start at its state");
                from_new += 1;
            }
        }
        assert!(from_new > 0, "grown shard never joined the stream");
    }

    #[test]
    fn gather_async_drains_tombstoned_shard() {
        let ws = workers(2);
        let registry = ShardRegistry::new(ws);
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            Some((w.id, w.counter))
        })
        .gather_async(2);
        for _ in 0..6 {
            assert!(it.next().is_some());
        }
        registry.retire(1);
        // The membership scan runs before the next pop, so the retired
        // shard's in-flight items (num_async = 2) are discarded by the
        // drain path — none may surface.  The stream keeps flowing off
        // the survivor.
        let mut retired_items = 0;
        for _ in 0..24 {
            let (id, _) = it.next().expect("stream survives scale-down");
            if id == 1 {
                retired_items += 1;
            }
        }
        assert_eq!(
            retired_items, 0,
            "tombstoned shard's in-flight items must be drained, not \
             yielded"
        );
        // Publishing into the slot rejoins it (scale back up).
        registry.publish(1, replacement(9));
        let mut rejoined = 0;
        for _ in 0..32 {
            if it.next().unwrap().0 == 9 {
                rejoined += 1;
            }
        }
        assert!(rejoined > 0, "revived slot never rejoined");
    }

    #[test]
    fn grow_retire_cycles_keep_streaming() {
        // Raw-registry grow/retire cycles (fresh slot per cycle, no
        // WorkerSet tombstone reuse): each retire must hand the
        // shard's queue budget back once its in-flight completions
        // drain.  An over-release starves the survivor (the gather
        // deadlocks — caught by the harness timeout); an under-release
        // is the unbounded-inflation bug this guards against.
        let ws = workers(1);
        let registry = ShardRegistry::new(ws);
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            Some((w.id, w.counter))
        })
        .gather_async(2);
        for cycle in 0..5 {
            let id = 100 + cycle;
            let idx = registry.grow(replacement(id)).unwrap();
            assert_eq!(idx, 1 + cycle);
            let mut from_new = 0;
            for _ in 0..48 {
                if it.next().unwrap().0 == id {
                    from_new += 1;
                }
            }
            assert!(from_new > 0, "cycle {cycle}: grown shard never joined");
            registry.retire(idx);
            // Tombstone drains; the survivor keeps the stream alive.
            for _ in 0..16 {
                let (sid, _) =
                    it.next().expect("stream stalled after retire");
                assert_ne!(sid, id, "cycle {cycle}: tombstoned item leaked");
            }
        }
    }

    #[test]
    fn gather_sync_grow_retire_cycles_keep_round_sizes() {
        let ws = workers(1);
        let registry = ShardRegistry::new(ws);
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap().len(), 1);
        for cycle in 0..4usize {
            let idx = registry.grow(replacement(cycle)).unwrap();
            assert_eq!(
                it.next().unwrap().len(),
                2,
                "cycle {cycle}: grown shard missing from the round"
            );
            registry.retire(idx);
            assert_eq!(
                it.next().unwrap().len(),
                1,
                "cycle {cycle}: tombstone still in the round"
            );
        }
    }

    #[test]
    fn gather_sync_admits_growth_at_round_boundary_only() {
        let ws = workers(2);
        let registry = ShardRegistry::new(ws);
        let reg2 = registry.clone();
        let grown = std::sync::atomic::AtomicBool::new(false);
        // Worker 0 grows the registry from inside its round-2 plan
        // call — i.e. strictly mid-round.  The barrier that is
        // collecting must NOT admit the new shard; the next round must.
        let mut it = ParIter::from_registry(registry.clone(), move |w| {
            w.counter += 1;
            if w.id == 0
                && w.counter == 2
                && !grown.swap(true, std::sync::atomic::Ordering::SeqCst)
            {
                reg2.grow(replacement(5)).unwrap();
            }
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap(), vec![1, 1]);
        // Round 2: the grow happens while this barrier is in flight.
        assert_eq!(
            it.next().unwrap(),
            vec![2, 2],
            "sync gather admitted a shard mid-round"
        );
        // Round 3: boundary reached after the grow -> admitted.
        assert_eq!(it.next().unwrap(), vec![3, 3, 1001]);
        assert_eq!(it.next().unwrap(), vec![4, 4, 1002]);
    }

    #[test]
    fn gather_sync_drops_tombstoned_shard_at_next_boundary() {
        let ws = workers(3);
        let registry = ShardRegistry::new(ws);
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            Some(w.counter)
        })
        .gather_sync();
        assert_eq!(it.next().unwrap(), vec![1, 1, 1]);
        registry.retire(2);
        assert_eq!(it.next().unwrap(), vec![2, 2]);
        assert_eq!(it.next().unwrap(), vec![3, 3]);
        // Revive the slot: rejoins at the next boundary.
        registry.publish(2, replacement(4));
        assert_eq!(it.next().unwrap(), vec![4, 4, 1001]);
    }

    #[test]
    fn gather_async_ends_when_every_shard_is_tombstoned() {
        let ws = workers(2);
        let registry = ShardRegistry::new(ws);
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            Some(w.counter)
        })
        .gather_async(1);
        assert!(it.next().is_some());
        registry.retire(0);
        registry.retire(1);
        // In-flight completions drain, then the stream ends cleanly
        // (and stays ended) instead of parking forever.
        let mut remaining = 0;
        while it.next().is_some() {
            remaining += 1;
            assert!(remaining < 8, "stream did not end after full retire");
        }
        assert_eq!(it.next(), None);
    }

    // -----------------------------------------------------------------
    // Deadline supervision: wedged shards are written off, not waited on
    // -----------------------------------------------------------------

    #[test]
    fn gather_async_deadline_writes_off_hung_shard() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let ws = workers(2);
        let registry = ShardRegistry::new(ws.clone());
        let release = Arc::new(AtomicBool::new(false));
        let r2 = release.clone();
        let sup = DeadlineSupervision::new(Duration::from_millis(80));
        let counters = sup.counters.clone();
        let mut it = ParIter::from_registry(registry.clone(), move |w| {
            w.counter += 1;
            if w.id == 1 && w.counter == 2 {
                // Wedge: no reply, no panic — the guard never fires.
                while !r2.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            Some((w.id, w.counter))
        })
        .gather_async_deadline(1, sup);
        // The stream keeps flowing off shard 0 until the deadline
        // declares the wedged shard suspect (the timed pop clamps to
        // zero once shard 1 is overdue, so the survivor's items never
        // postpone the write-off).
        let mut pulls = 0;
        let mut from_wedged = 0;
        while counters.snapshot().suspects == 0 {
            let (id, _) =
                it.next().expect("stream parked behind a wedged shard");
            if id == 1 {
                from_wedged += 1;
            }
            pulls += 1;
            assert!(pulls < 100_000, "suspect never declared");
        }
        // Only the wedged shard's pre-wedge item (counter 1) may have
        // surfaced.
        assert!(from_wedged <= 1, "wedged shard kept yielding");
        assert_eq!(counters.snapshot().suspects, 1);
        // The suspect was force-poisoned (cooperative kill)...
        assert!(ws[1].await_poisoned(Duration::from_secs(2)));
        // ...and a published replacement rejoins the same live stream.
        registry.publish(1, replacement(1));
        let mut rejoined = 0;
        for _ in 0..64 {
            let (id, c) = it.next().unwrap();
            if id == 1 {
                assert!(c > 1000, "item from the wedged incarnation: {c}");
                rejoined += 1;
            }
        }
        assert!(rejoined > 0, "replacement never rejoined after write-off");
        release.store(true, Ordering::SeqCst);
    }

    #[test]
    fn gather_sync_deadline_degrades_round_to_quorum() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let ws = workers(3);
        let registry = ShardRegistry::new(ws.clone());
        let release = Arc::new(AtomicBool::new(false));
        let r2 = release.clone();
        let sup = DeadlineSupervision::new(Duration::from_millis(60));
        let counters = sup.counters.clone();
        let mut it = ParIter::from_registry(registry.clone(), move |w| {
            w.counter += 1;
            if w.id == 2 && w.counter == 2 {
                while !r2.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            Some(w.counter)
        })
        .gather_sync_deadline(sup);
        assert_eq!(it.next().unwrap(), vec![1, 1, 1]);
        // Round 2: shard 2 wedges; the barrier times out and completes
        // off the survivors instead of parking forever.
        assert_eq!(it.next().unwrap(), vec![2, 2]);
        assert_eq!(counters.snapshot().suspects, 1);
        assert!(ws[2].await_poisoned(Duration::from_secs(2)));
        assert_eq!(it.next().unwrap(), vec![3, 3]);
        // A published replacement rejoins at the next round boundary.
        registry.publish(2, replacement(2));
        assert_eq!(it.next().unwrap(), vec![4, 4, 1001]);
        release.store(true, Ordering::SeqCst);
    }

    #[test]
    fn deadline_tolerates_slow_but_live_shards() {
        let ws = workers(2);
        let sup = DeadlineSupervision::new(Duration::from_secs(5));
        let counters = sup.counters.clone();
        let mut it = ParIter::from_actors(ws, |w| {
            w.counter += 1;
            std::thread::sleep(Duration::from_millis(5));
            Some(w.counter)
        })
        .gather_sync_deadline(sup);
        for round in 1..=3 {
            assert_eq!(it.next().unwrap(), vec![round, round]);
        }
        assert_eq!(
            counters.snapshot(),
            crate::actor::FaultStats::default(),
            "healthy-but-slow shards must not be declared suspect"
        );
    }

    #[test]
    fn exhausted_shard_is_not_resurrected_by_publish() {
        let ws = workers(1);
        let registry = ShardRegistry::new(ws);
        let mut it = ParIter::from_registry(registry.clone(), |w| {
            w.counter += 1;
            if w.counter > 2 {
                None
            } else {
                Some(w.counter)
            }
        })
        .gather_async(1);
        assert_eq!(it.next(), Some(1));
        assert_eq!(it.next(), Some(2));
        assert_eq!(it.next(), None);
        // A publish after clean exhaustion must not reopen the stream.
        registry.publish(0, replacement(0));
        assert_eq!(it.next(), None);
    }
}
