//! Trivial environment for the sampling microbenchmark (paper Fig. 13a):
//! fixed-length episodes, constant reward, negligible step cost — so the
//! measured throughput is pure system overhead.

use super::Env;

#[derive(Debug, Clone)]
pub struct DummyEnv {
    obs_dim: usize,
    episode_len: usize,
    steps: usize,
}

impl DummyEnv {
    pub fn new(obs_dim: usize, episode_len: usize) -> Self {
        super::note_env_constructed();
        DummyEnv { obs_dim, episode_len, steps: 0 }
    }
}

impl Env for DummyEnv {
    fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    fn num_actions(&self) -> usize {
        2
    }

    fn reset_into(&mut self, obs_out: &mut [f32]) {
        self.steps = 0;
        obs_out.fill(0.0);
    }

    fn step_into(&mut self, _action: i32, obs_out: &mut [f32]) -> (f32, bool) {
        self.steps += 1;
        obs_out.fill(0.0);
        (1.0, self.steps >= self.episode_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_episode_length() {
        let mut env = DummyEnv::new(4, 10);
        env.reset();
        for i in 1..=10 {
            let (_, r, done) = env.step(0);
            assert_eq!(r, 1.0);
            assert_eq!(done, i == 10);
        }
        env.reset();
        let (_, _, done) = env.step(1);
        assert!(!done);
    }
}
