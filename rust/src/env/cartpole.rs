//! CartPole physics, ported from the classic Gym implementation
//! (Barto, Sutton & Anderson 1983 dynamics, Euler integration, the exact
//! Gym constants and termination thresholds).

use super::Env;
use crate::util::Rng;

/// Dynamics parameters; the defaults are Gym's CartPole-v0.
/// `TaskCartPole` perturbs these to build the MAML task distribution.
#[derive(Debug, Clone)]
pub struct CartPoleParams {
    pub gravity: f32,
    pub masscart: f32,
    pub masspole: f32,
    pub pole_half_length: f32,
    pub force_mag: f32,
    pub tau: f32,
    /// Episode step limit (v0: 200, v1: 500).
    pub max_steps: usize,
}

impl Default for CartPoleParams {
    fn default() -> Self {
        CartPoleParams {
            gravity: 9.8,
            masscart: 1.0,
            masspole: 0.1,
            pole_half_length: 0.5,
            force_mag: 10.0,
            tau: 0.02,
            max_steps: 200,
        }
    }
}

#[derive(Debug, Clone)]
pub struct CartPole {
    params: CartPoleParams,
    state: [f32; 4], // x, x_dot, theta, theta_dot
    steps: usize,
    done: bool,
    rng: Rng,
}

const X_THRESHOLD: f32 = 2.4;
const THETA_THRESHOLD: f32 = 12.0 * std::f32::consts::PI / 180.0;

impl CartPole {
    pub fn new(seed: u64) -> Self {
        Self::with_params(CartPoleParams::default(), seed)
    }

    pub fn with_params(params: CartPoleParams, seed: u64) -> Self {
        super::note_env_constructed();
        let mut env = CartPole {
            params,
            state: [0.0; 4],
            steps: 0,
            done: true,
            rng: Rng::new(seed),
        };
        env.reset();
        env
    }

    pub fn params(&self) -> &CartPoleParams {
        &self.params
    }

    /// Advance the physics one step; returns (reward, done).
    fn advance(&mut self, action: i32) -> (f32, bool) {
        assert!(!self.done, "step() called on a done episode; call reset()");
        let p = &self.params;
        let force = if action == 1 { p.force_mag } else { -p.force_mag };
        let [x, x_dot, theta, theta_dot] = self.state;
        let total_mass = p.masscart + p.masspole;
        let polemass_length = p.masspole * p.pole_half_length;

        let costheta = theta.cos();
        let sintheta = theta.sin();
        let temp =
            (force + polemass_length * theta_dot * theta_dot * sintheta)
                / total_mass;
        let thetaacc = (p.gravity * sintheta - costheta * temp)
            / (p.pole_half_length
                * (4.0 / 3.0 - p.masspole * costheta * costheta / total_mass));
        let xacc = temp - polemass_length * thetaacc * costheta / total_mass;

        self.state = [
            x + p.tau * x_dot,
            x_dot + p.tau * xacc,
            theta + p.tau * theta_dot,
            theta_dot + p.tau * thetaacc,
        ];
        self.steps += 1;

        let fell = self.state[0].abs() > X_THRESHOLD
            || self.state[2].abs() > THETA_THRESHOLD;
        let timeout = self.steps >= self.params.max_steps;
        self.done = fell || timeout;
        (1.0, self.done)
    }
}

impl Env for CartPole {
    fn obs_dim(&self) -> usize {
        4
    }

    fn num_actions(&self) -> usize {
        2
    }

    fn reset_into(&mut self, obs_out: &mut [f32]) {
        for s in &mut self.state {
            *s = self.rng.uniform_range(-0.05, 0.05);
        }
        self.steps = 0;
        self.done = false;
        obs_out.copy_from_slice(&self.state);
    }

    fn step_into(&mut self, action: i32, obs_out: &mut [f32]) -> (f32, bool) {
        let out = self.advance(action);
        obs_out.copy_from_slice(&self.state);
        out
    }
}

/// CartPole with randomized dynamics — the MAML task distribution.
/// Each `sample_task` draws new pole length / gravity / force scaling;
/// the policy must adapt to the drawn dynamics from a few fragments.
#[derive(Debug, Clone)]
pub struct TaskCartPole {
    inner: CartPole,
    task_rng: Rng,
    seed: u64,
}

impl TaskCartPole {
    pub fn new(seed: u64) -> Self {
        TaskCartPole {
            inner: CartPole::new(seed),
            task_rng: Rng::new(seed ^ 0xDEADBEEF),
            seed,
        }
    }

    /// Draw a new dynamics task; returns the task parameters used.
    pub fn sample_task(&mut self) -> CartPoleParams {
        let params = CartPoleParams {
            pole_half_length: self.task_rng.uniform_range(0.25, 1.0),
            gravity: self.task_rng.uniform_range(7.0, 12.0),
            force_mag: self.task_rng.uniform_range(7.0, 13.0),
            ..CartPoleParams::default()
        };
        self.set_task(params.clone());
        params
    }

    pub fn set_task(&mut self, params: CartPoleParams) {
        self.seed = self.seed.wrapping_add(1);
        self.inner = CartPole::with_params(params, self.seed);
    }
}

impl Env for TaskCartPole {
    fn obs_dim(&self) -> usize {
        self.inner.obs_dim()
    }
    fn num_actions(&self) -> usize {
        self.inner.num_actions()
    }
    fn reset_into(&mut self, obs_out: &mut [f32]) {
        self.inner.reset_into(obs_out)
    }
    fn step_into(&mut self, action: i32, obs_out: &mut [f32]) -> (f32, bool) {
        self.inner.step_into(action, obs_out)
    }
    fn sample_task(&mut self) {
        TaskCartPole::sample_task(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_state_is_small() {
        let mut env = CartPole::new(0);
        let obs = env.reset();
        assert_eq!(obs.len(), 4);
        assert!(obs.iter().all(|v| v.abs() <= 0.05));
    }

    #[test]
    fn episode_terminates() {
        let mut env = CartPole::new(1);
        env.reset();
        // Always push right: pole must fall well before the step limit.
        let mut steps = 0;
        loop {
            let (_, r, done) = env.step(1);
            assert_eq!(r, 1.0);
            steps += 1;
            if done {
                break;
            }
            assert!(steps < 200, "pole never fell under constant force");
        }
        assert!(steps < 60, "constant push should fall fast, took {steps}");
    }

    #[test]
    fn step_limit_caps_episode() {
        let mut env = CartPole::new(2);
        env.reset();
        // Alternate actions as a crude balance; count an upper bound.
        let mut steps = 0;
        let mut act = 0;
        loop {
            let (_, _, done) = env.step(act);
            act = 1 - act;
            steps += 1;
            if done {
                break;
            }
        }
        assert!(steps <= 200);
    }

    #[test]
    #[should_panic(expected = "done episode")]
    fn step_after_done_panics() {
        let mut env = CartPole::new(3);
        env.reset();
        loop {
            let (_, _, done) = env.step(1);
            if done {
                break;
            }
        }
        env.step(1);
    }

    #[test]
    fn deterministic_given_seed_and_actions() {
        let run = || {
            let mut env = CartPole::new(42);
            let mut trace = vec![env.reset()];
            for i in 0..50 {
                if env.done {
                    trace.push(env.reset());
                } else {
                    let (o, _, _) = env.step((i % 2) as i32);
                    trace.push(o);
                }
            }
            trace
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn task_cartpole_samples_varied_dynamics() {
        let mut env = TaskCartPole::new(0);
        let a = env.sample_task();
        let b = env.sample_task();
        assert_ne!(a.pole_half_length, b.pole_half_length);
        assert!((0.25..1.0).contains(&a.pole_half_length));
        assert!((7.0..12.0).contains(&a.gravity));
        // Env remains steppable after task switch.
        env.reset();
        env.step(0);
    }
}
