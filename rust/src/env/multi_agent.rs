//! Multi-agent CartPole: N agents, each driving an independent CartPole,
//! stepped in lockstep.  Agent i is bound to a policy id by the
//! `policy_mapping` function — the workload for the PPO+DQN composition
//! experiment (paper Fig. 11/12/14, "four agents per policy").

use std::collections::BTreeMap;

use super::{CartPole, Env};

pub struct MultiAgentCartPole {
    agents: Vec<CartPole>,
    policy_mapping: Box<dyn Fn(usize) -> String + Send>,
}

impl MultiAgentCartPole {
    pub fn new(
        num_agents: usize,
        seed: u64,
        policy_mapping: impl Fn(usize) -> String + Send + 'static,
    ) -> Self {
        let agents = (0..num_agents)
            .map(|i| CartPole::new(seed.wrapping_add(i as u64)))
            .collect();
        MultiAgentCartPole { agents, policy_mapping: Box::new(policy_mapping) }
    }

    pub fn num_agents(&self) -> usize {
        self.agents.len()
    }

    pub fn obs_dim(&self) -> usize {
        4
    }

    pub fn num_actions(&self) -> usize {
        2
    }

    /// The policy id for agent `i`.
    pub fn policy_for(&self, agent: usize) -> String {
        (self.policy_mapping)(agent)
    }

    /// Reset all agents; returns obs per agent id.
    pub fn reset_all(&mut self) -> BTreeMap<usize, Vec<f32>> {
        self.agents
            .iter_mut()
            .enumerate()
            .map(|(i, e)| (i, e.reset()))
            .collect()
    }

    /// Step every agent with its action.  A done agent auto-resets (its
    /// transition reports done=true with the terminal reward, and the
    /// returned obs is the fresh reset — independent-episode semantics).
    pub fn step_all(
        &mut self,
        actions: &BTreeMap<usize, i32>,
    ) -> BTreeMap<usize, (Vec<f32>, f32, bool)> {
        let mut out = BTreeMap::new();
        for (i, env) in self.agents.iter_mut().enumerate() {
            let action = *actions.get(&i).expect("action for every agent");
            let (obs, reward, done) = env.step(action);
            let obs = if done { env.reset() } else { obs };
            out.insert(i, (obs, reward, done));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mapping(i: usize) -> String {
        if i % 2 == 0 { "ppo".into() } else { "dqn".into() }
    }

    #[test]
    fn agents_map_to_policies() {
        let env = MultiAgentCartPole::new(4, 0, mapping);
        assert_eq!(env.policy_for(0), "ppo");
        assert_eq!(env.policy_for(1), "dqn");
        assert_eq!(env.policy_for(2), "ppo");
    }

    #[test]
    fn step_all_returns_every_agent() {
        let mut env = MultiAgentCartPole::new(3, 1, mapping);
        let obs = env.reset_all();
        assert_eq!(obs.len(), 3);
        let actions: BTreeMap<usize, i32> =
            (0..3).map(|i| (i, (i % 2) as i32)).collect();
        let results = env.step_all(&actions);
        assert_eq!(results.len(), 3);
        for (_, (obs, r, _)) in results {
            assert_eq!(obs.len(), 4);
            assert_eq!(r, 1.0);
        }
    }

    #[test]
    fn done_agent_auto_resets() {
        let mut env = MultiAgentCartPole::new(1, 2, mapping);
        env.reset_all();
        let actions: BTreeMap<usize, i32> = [(0, 1)].into();
        // Push right until done; the step reporting done must return a
        // fresh (small) reset obs so the episode stream never stalls.
        for _ in 0..500 {
            let out = env.step_all(&actions);
            let (obs, _, done) = &out[&0];
            if *done {
                assert!(obs.iter().all(|v| v.abs() <= 0.05));
                return;
            }
        }
        panic!("episode never terminated");
    }
}
