//! Environment substrate (the paper used OpenAI Gym / multi-agent Atari;
//! see DESIGN.md §Substitutions).
//!
//! * [`CartPole`] — physics port of Gym CartPole-v0/v1.
//! * [`MultiAgentCartPole`] — N agents, each its own CartPole instance,
//!   mapped to policies via an agent→policy function (the multi-agent
//!   composition workload of Fig. 11/14).
//! * [`TaskCartPole`] — CartPole with perturbable dynamics (pole length /
//!   gravity), the task distribution for the MAML case study.
//! * [`DummyEnv`] — trivial env for the sampling microbenchmark
//!   (Fig. 13a isolates system overhead with a dummy policy).
//! * [`EpisodeGateway`] — the *external*-env front end: a session table
//!   serving actions to client-owned envs over a
//!   start/submit/take/reward/end protocol, with batched inference,
//!   admission control, and idle-deadline reaping (see
//!   `ops::gateway_ops` for the actor/service layer).

mod cartpole;
mod dummy;
pub mod external;
mod mountain_car;
mod multi_agent;

pub use cartpole::{CartPole, CartPoleParams, TaskCartPole};
pub use dummy::DummyEnv;
pub use external::{
    EpisodeGateway, GatewayBacklogStats, GatewayConfig, GatewayShardStats,
    SessionError, SessionId,
};
pub use mountain_car::MountainCar;
pub use multi_agent::MultiAgentCartPole;

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-global count of env instances ever constructed.  Offline
/// plans advertise "train with zero envs"; this makes that claim
/// checkable (`tests/offline.rs` asserts the counter does not move
/// while `offline_dqn_plan` runs) instead of rhetorical.
static ENV_CONSTRUCTIONS: AtomicU64 = AtomicU64::new(0);

/// Called by every concrete env constructor.
pub(crate) fn note_env_constructed() {
    ENV_CONSTRUCTIONS.fetch_add(1, Ordering::Relaxed);
}

/// Lifetime count of env instances constructed in this process.
pub fn constructed_count() -> u64 {
    ENV_CONSTRUCTIONS.load(Ordering::Relaxed)
}

/// A single-agent episodic environment with f32 vector observations and
/// discrete actions.
///
/// The *buffer-writing* forms are the canonical interface: the rollout
/// hot loop steps N envs per worker through preallocated flat buffers,
/// so `reset_into`/`step_into` are what every env must implement.  The
/// allocating `reset`/`step` are convenience wrappers (tests, one-off
/// probes) provided for free on top of them.
pub trait Env: Send {
    /// Observation dimensionality.
    fn obs_dim(&self) -> usize;
    /// Number of discrete actions.
    fn num_actions(&self) -> usize;
    /// Reset, writing the initial observation into `obs_out`
    /// (`obs_out.len() == obs_dim()`).
    fn reset_into(&mut self, obs_out: &mut [f32]);
    /// Apply `action`, writing the next observation into `obs_out`;
    /// returns (reward, done).
    fn step_into(&mut self, action: i32, obs_out: &mut [f32]) -> (f32, bool);
    /// Reset and return the initial observation.  Convenience wrapper
    /// over [`Env::reset_into`] — allocates one `Vec` per call, so keep
    /// it off hot paths.
    fn reset(&mut self) -> Vec<f32> {
        let mut obs = vec![0.0; self.obs_dim()];
        self.reset_into(&mut obs);
        obs
    }
    /// Apply `action`; returns (next_obs, reward, done).  Convenience
    /// wrapper over [`Env::step_into`].
    fn step(&mut self, action: i32) -> (Vec<f32>, f32, bool) {
        let mut obs = vec![0.0; self.obs_dim()];
        let (reward, done) = self.step_into(action, &mut obs);
        (obs, reward, done)
    }
    /// Draw a new task from the env's task distribution (meta-learning
    /// envs only; default no-op).  Callers must `reset()` afterwards.
    fn sample_task(&mut self) {}
}
