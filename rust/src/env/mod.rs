//! Environment substrate (the paper used OpenAI Gym / multi-agent Atari;
//! see DESIGN.md §Substitutions).
//!
//! * [`CartPole`] — physics port of Gym CartPole-v0/v1.
//! * [`MultiAgentCartPole`] — N agents, each its own CartPole instance,
//!   mapped to policies via an agent→policy function (the multi-agent
//!   composition workload of Fig. 11/14).
//! * [`TaskCartPole`] — CartPole with perturbable dynamics (pole length /
//!   gravity), the task distribution for the MAML case study.
//! * [`DummyEnv`] — trivial env for the sampling microbenchmark
//!   (Fig. 13a isolates system overhead with a dummy policy).

mod cartpole;
mod dummy;
mod mountain_car;
mod multi_agent;

pub use cartpole::{CartPole, CartPoleParams, TaskCartPole};
pub use dummy::DummyEnv;
pub use mountain_car::MountainCar;
pub use multi_agent::MultiAgentCartPole;

/// A single-agent episodic environment with f32 vector observations and
/// discrete actions.
pub trait Env: Send {
    /// Observation dimensionality.
    fn obs_dim(&self) -> usize;
    /// Number of discrete actions.
    fn num_actions(&self) -> usize;
    /// Reset and return the initial observation.
    fn reset(&mut self) -> Vec<f32>;
    /// Apply `action`; returns (next_obs, reward, done).
    fn step(&mut self, action: i32) -> (Vec<f32>, f32, bool);
    /// Reset, writing the initial observation into `obs_out`
    /// (`obs_out.len() == obs_dim()`).  The default delegates to
    /// [`Env::reset`] and copies; concrete envs override to write in
    /// place so the rollout hot loop stays allocation-free.
    fn reset_into(&mut self, obs_out: &mut [f32]) {
        let obs = self.reset();
        obs_out.copy_from_slice(&obs);
    }
    /// Apply `action`, writing the next observation into `obs_out`;
    /// returns (reward, done).  Default delegates to [`Env::step`] and
    /// copies; concrete envs override to avoid the per-step `Vec<f32>`.
    fn step_into(&mut self, action: i32, obs_out: &mut [f32]) -> (f32, bool) {
        let (obs, reward, done) = self.step(action);
        obs_out.copy_from_slice(&obs);
        (reward, done)
    }
    /// Draw a new task from the env's task distribution (meta-learning
    /// envs only; default no-op).  Callers must `reset()` afterwards.
    fn sample_task(&mut self) {}
}
