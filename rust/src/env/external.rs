//! External-episode gateway: serve policies to **client-owned envs**.
//!
//! RLlib's production deployment mode is "externally connected
//! simulators": the environment lives outside the trainer (a game
//! client, a web service, a robot), and calls in for actions.  The
//! [`EpisodeGateway`] is the session table at the heart of that front
//! end — a fixed-capacity table of concurrent client episodes, each
//! driven by the protocol
//!
//! ```text
//! start_episode -> (submit_obs -> take_action -> log_reward)* -> end_episode
//! ```
//!
//! The gateway's job is **multiplexing onto the batched-inference
//! path**: pending action requests from many sessions are coalesced
//! into one flat `[N, obs_dim]` buffer and served by a single
//! [`Policy::compute_actions_into`] forward per [`EpisodeGateway::tick`]
//! — one forward per *tick*, not one per client.  That is the same
//! amortization the vectorized rollout loop gets, applied to traffic
//! the trainer does not control.
//!
//! Three pieces of load discipline live here (the actor/service layer
//! in `ops::gateway_ops` adds mailbox backpressure on top):
//!
//! * **Admission control** — `start_episode` sheds new sessions once
//!   the table holds `max_sessions` live episodes (counted, so the
//!   autoscaler can react to sustained shedding).
//! * **Deadline reaping** — every session carries an idle deadline;
//!   [`EpisodeGateway::reap_idle`] writes off clients silent past it
//!   through a per-session forgiveness ledger (the deadline-supervision
//!   idiom): one missed deadline earns a strike, `forgiveness + 1`
//!   strikes reap the session and free its slot.  Any client activity
//!   clears the ledger.
//! * **Stale-session fencing** — a [`SessionId`] embeds a nonce, so a
//!   client holding a reaped (and possibly reused) slot gets
//!   [`SessionError::Expired`], never another client's episode.
//!
//! Completed episodes surface as [`crate::metrics::EpisodeRecord`]s,
//! and — because the gateway sees (obs, action, reward, next_obs)
//! per transition — every served episode is also *experience*:
//! transitions accumulate in a fragment builder drained by the
//! train-from-gateway plan (`algorithms::external`) into the replay
//! service.

use crate::metrics::EpisodeRecord;
use crate::policy::{ActionOutput, Policy};
use crate::sample_batch::{SampleBatch, SampleBatchBuilder};

/// Knobs of one gateway shard's session table.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Observation dimensionality every session must submit.
    pub obs_dim: usize,
    /// Admission watermark: live sessions at or above this shed new
    /// `start_episode` calls.
    pub max_sessions: usize,
    /// Idle deadline in nanoseconds: a session with no client activity
    /// for this long earns a strike on each `reap_idle` pass.
    pub idle_deadline_ns: u64,
    /// Missed deadlines forgiven before a session is reaped.  0 = reap
    /// on the first strike.
    pub forgiveness: u32,
    /// Transitions per experience fragment drained to the trainer.
    pub fragment: usize,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            obs_dim: 4,
            max_sessions: 1024,
            idle_deadline_ns: 5_000_000_000, // 5s
            forgiveness: 1,
            fragment: 64,
        }
    }
}

/// Handle to one live episode: table slot + a nonce fencing reuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId {
    pub slot: u32,
    pub nonce: u32,
}

/// Why a gateway call failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionError {
    /// Admission control: the table is at its watermark.
    Shed,
    /// The session was reaped (idle past deadline) or already ended —
    /// or the slot was since reused by another client (nonce mismatch).
    Expired,
    /// Protocol misuse: e.g. `submit_obs` while an action is already
    /// pending, or `take_action` before any obs was submitted.
    Protocol(&'static str),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Shed => write!(f, "session shed by admission control"),
            SessionError::Expired => write!(f, "session expired"),
            SessionError::Protocol(what) => {
                write!(f, "session protocol violation: {what}")
            }
        }
    }
}

/// Where one session sits in the request/serve cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Waiting for the client's (first or next) observation.
    AwaitingObs,
    /// Observation queued for the next batched forward.
    Pending,
    /// Action computed; waiting for the client to take it.
    ActionReady,
}

struct Session {
    nonce: u32,
    phase: Phase,
    /// The observation submitted for the pending/served forward; after
    /// the action is taken it becomes the transition's "current obs".
    obs: Vec<f32>,
    /// The action served for `obs` (valid in ActionReady/AwaitingObs
    /// with `has_prev` set).
    action: ActionOutput,
    /// A transition (obs, action) is outstanding: the next submitted
    /// obs (or episode end) completes it.
    has_prev: bool,
    /// Reward logged since the last served action.
    reward_acc: f32,
    episode_reward: f64,
    episode_len: usize,
    /// Nanos of the last client activity (admission/obs/take/reward).
    last_activity_ns: u64,
    /// Nanos when the pending obs was submitted (action latency start).
    submitted_ns: u64,
    /// Forgiveness ledger: missed idle deadlines so far.
    strikes: u32,
}

/// Counters one gateway shard accumulates (monotone, lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GatewayShardStats {
    pub live_sessions: usize,
    pub pending_requests: usize,
    pub started: u64,
    pub shed: u64,
    pub reaped: u64,
    pub completed: u64,
    pub expired_calls: u64,
    pub ticks: u64,
    pub batched_rows: u64,
    pub max_batch_fill: u64,
    /// p99 action latency over the recent-sample window, microseconds.
    pub p99_action_latency_us: f64,
    pub transitions: u64,
}

/// Service-level backlog snapshot: every gateway shard's session table
/// + mailbox pressure folded together (the gateway analogue of
/// `replay::ReplayBacklogStats`).  Attached to `TrainResult::gateway`
/// and consumed by `Autoscaler::gateway_signals`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GatewayBacklogStats {
    /// Live (non-tombstoned) gateway shards.
    pub live_shards: usize,
    /// Total registry slots (incl. tombstones).
    pub slots: usize,
    /// Sessions currently held across all live shards.
    pub sessions: usize,
    /// Action requests waiting for a batched forward.
    pub pending: usize,
    /// Deepest live shard mailbox (current).
    pub max_queue_len: usize,
    /// Deepest live shard mailbox (lifetime high water).
    pub max_queue_hwm: usize,
    pub started: u64,
    /// Sessions shed by admission control (watermark) plus client casts
    /// shed by mailbox backpressure.
    pub shed: u64,
    pub reaped: u64,
    pub completed: u64,
    pub ticks: u64,
    pub batched_rows: u64,
    /// Largest single-forward coalesced batch any shard served.
    pub max_batch_fill: u64,
    /// Worst per-shard p99 action latency, microseconds.
    pub p99_action_latency_us: f64,
    pub transitions: u64,
}

/// Latency window size for the p99 estimate (recent samples, ring).
const LAT_WINDOW: usize = 512;

/// The session table of one gateway shard.  Single-threaded by design:
/// it lives inside a gateway actor (`ops::gateway_ops`), which provides
/// the mailbox, supervision, and elasticity around it.
pub struct EpisodeGateway {
    cfg: GatewayConfig,
    sessions: Vec<Option<Session>>,
    free: Vec<u32>,
    next_nonce: u32,
    /// Slots with a queued observation, in submission order.
    pending: Vec<u32>,
    /// Flat `[N, obs_dim]` scratch the tick coalesces into.
    obs_scratch: Vec<f32>,
    /// Action outputs of the last tick (parallel to its batch rows).
    actions_scratch: Vec<ActionOutput>,
    /// Recent action latencies (ns), ring-buffered for the p99.
    lat_ring: Vec<u64>,
    lat_next: usize,
    lat_sort_scratch: Vec<u64>,
    /// Completed-episode records, drained by metrics reporting.
    episodes: Vec<EpisodeRecord>,
    /// Experience fragments under construction / ready to drain.
    builder: SampleBatchBuilder,
    stats: GatewayShardStats,
}

impl EpisodeGateway {
    pub fn new(cfg: GatewayConfig) -> Self {
        assert!(cfg.obs_dim > 0, "gateway obs_dim must be positive");
        assert!(cfg.max_sessions > 0, "gateway max_sessions must be positive");
        let fragment = cfg.fragment.max(1);
        EpisodeGateway {
            sessions: Vec::new(),
            free: Vec::new(),
            next_nonce: 1,
            pending: Vec::new(),
            obs_scratch: Vec::new(),
            actions_scratch: Vec::new(),
            lat_ring: Vec::with_capacity(LAT_WINDOW),
            lat_next: 0,
            lat_sort_scratch: Vec::with_capacity(LAT_WINDOW),
            episodes: Vec::new(),
            builder: SampleBatchBuilder::with_capacity(cfg.obs_dim, fragment),
            stats: GatewayShardStats::default(),
            cfg,
        }
    }

    pub fn config(&self) -> &GatewayConfig {
        &self.cfg
    }

    /// Live sessions currently held.
    pub fn live_sessions(&self) -> usize {
        self.stats.live_sessions
    }

    /// Action requests queued for the next tick.
    pub fn pending_requests(&self) -> usize {
        self.pending.len()
    }

    /// Snapshot of this shard's counters (p99 computed on demand).
    pub fn stats(&mut self) -> GatewayShardStats {
        let mut s = self.stats;
        s.pending_requests = self.pending.len();
        s.p99_action_latency_us = self.p99_latency_us();
        s
    }

    fn p99_latency_us(&mut self) -> f64 {
        if self.lat_ring.is_empty() {
            return 0.0;
        }
        self.lat_sort_scratch.clear();
        self.lat_sort_scratch.extend_from_slice(&self.lat_ring);
        self.lat_sort_scratch.sort_unstable();
        let n = self.lat_sort_scratch.len();
        let idx = ((n as f64 * 0.99).ceil() as usize).clamp(1, n) - 1;
        self.lat_sort_scratch[idx] as f64 / 1_000.0
    }

    fn record_latency(&mut self, ns: u64) {
        if self.lat_ring.len() < LAT_WINDOW {
            self.lat_ring.push(ns);
        } else {
            self.lat_ring[self.lat_next] = ns;
        }
        self.lat_next = (self.lat_next + 1) % LAT_WINDOW;
    }

    fn session_mut(
        &mut self,
        id: SessionId,
    ) -> Result<&mut Session, SessionError> {
        let live = matches!(
            self.sessions.get(id.slot as usize).and_then(|s| s.as_ref()),
            Some(s) if s.nonce == id.nonce
        );
        if live {
            Ok(self.sessions[id.slot as usize].as_mut().unwrap())
        } else {
            self.stats.expired_calls += 1;
            Err(SessionError::Expired)
        }
    }

    /// Open a new episode.  Sheds (counts + errors) at the admission
    /// watermark.
    pub fn start_episode(
        &mut self,
        now_ns: u64,
    ) -> Result<SessionId, SessionError> {
        if self.stats.live_sessions >= self.cfg.max_sessions {
            self.stats.shed += 1;
            return Err(SessionError::Shed);
        }
        let nonce = self.next_nonce;
        self.next_nonce = self.next_nonce.wrapping_add(1).max(1);
        let session = Session {
            nonce,
            phase: Phase::AwaitingObs,
            obs: vec![0.0; self.cfg.obs_dim],
            action: ActionOutput { action: 0, logp: 0.0, value: 0.0 },
            has_prev: false,
            reward_acc: 0.0,
            episode_reward: 0.0,
            episode_len: 0,
            last_activity_ns: now_ns,
            submitted_ns: now_ns,
            strikes: 0,
        };
        let slot = match self.free.pop() {
            Some(slot) => {
                self.sessions[slot as usize] = Some(session);
                slot
            }
            None => {
                self.sessions.push(Some(session));
                (self.sessions.len() - 1) as u32
            }
        };
        self.stats.live_sessions += 1;
        self.stats.started += 1;
        Ok(SessionId { slot, nonce })
    }

    /// Submit the episode's next observation, queueing an action
    /// request for the coming tick.  Completes the previous transition
    /// (if an action was outstanding) into the experience fragment.
    pub fn submit_obs(
        &mut self,
        id: SessionId,
        obs: &[f32],
        now_ns: u64,
    ) -> Result<(), SessionError> {
        let obs_dim = self.cfg.obs_dim;
        assert_eq!(obs.len(), obs_dim, "gateway obs_dim mismatch");
        let s = self.session_mut(id)?;
        if s.phase != Phase::AwaitingObs {
            return Err(SessionError::Protocol(
                "submit_obs while an action request is outstanding",
            ));
        }
        s.last_activity_ns = now_ns;
        s.submitted_ns = now_ns;
        s.strikes = 0;
        s.phase = Phase::Pending;
        let (prev_done, action, reward) = if s.has_prev {
            s.has_prev = false;
            (true, s.action.action, std::mem::take(&mut s.reward_acc))
        } else {
            (false, 0, 0.0)
        };
        if prev_done {
            // Borrow dance: the builder and the session both live in
            // self, so stage through a local copy of the previous obs.
            let prev = std::mem::take(&mut s.obs);
            self.builder.add_transition(&prev, action, reward, obs, false);
            self.stats.transitions += 1;
            let s = self.sessions[id.slot as usize].as_mut().unwrap();
            s.obs = prev;
        }
        let s = self.sessions[id.slot as usize].as_mut().unwrap();
        s.obs.clear();
        s.obs.extend_from_slice(obs);
        self.pending.push(id.slot);
        Ok(())
    }

    /// Run one batched forward over every pending request: coalesce the
    /// queued observations into one flat `[N, obs_dim]` buffer, call
    /// `compute_actions_into` once, and mark each session's action
    /// ready.  Returns the batch fill (0 = nothing pending).
    // flowlint: hot-path (scratch buffers reused across ticks; pinned by tests/gateway_alloc.rs)
    pub fn tick(&mut self, policy: &mut dyn Policy, _now_ns: u64) -> usize {
        if self.pending.is_empty() {
            return 0;
        }
        let obs_dim = self.cfg.obs_dim;
        let mut batch = std::mem::take(&mut self.pending);
        // A session can be reaped between submit and tick: drop its row.
        batch.retain(|&slot| {
            matches!(
                self.sessions.get(slot as usize).and_then(|s| s.as_ref()),
                Some(s) if s.phase == Phase::Pending
            )
        });
        if batch.is_empty() {
            self.pending = batch;
            return 0;
        }
        let n = batch.len();
        self.obs_scratch.clear();
        self.obs_scratch.reserve(n * obs_dim);
        for &slot in &batch {
            let s = self.sessions[slot as usize].as_ref().unwrap();
            self.obs_scratch.extend_from_slice(&s.obs);
        }
        let mut actions = std::mem::take(&mut self.actions_scratch);
        policy.compute_actions_into(&self.obs_scratch, n, &mut actions);
        assert_eq!(actions.len(), n, "policy returned wrong action count");
        for (i, &slot) in batch.iter().enumerate() {
            let s = self.sessions[slot as usize].as_mut().unwrap();
            s.action = actions[i];
            s.phase = Phase::ActionReady;
        }
        self.actions_scratch = actions;
        batch.clear();
        self.pending = batch;
        self.stats.ticks += 1;
        self.stats.batched_rows += n as u64;
        self.stats.max_batch_fill = self.stats.max_batch_fill.max(n as u64);
        n
    }

    /// Take the served action for `id`.  `Ok(None)` means the request
    /// is still waiting for a tick.
    pub fn take_action(
        &mut self,
        id: SessionId,
        now_ns: u64,
    ) -> Result<Option<ActionOutput>, SessionError> {
        let s = self.session_mut(id)?;
        match s.phase {
            Phase::Pending => Ok(None),
            Phase::ActionReady => {
                s.phase = Phase::AwaitingObs;
                s.has_prev = true;
                s.episode_len += 1;
                s.last_activity_ns = now_ns;
                s.strikes = 0;
                let latency = now_ns.saturating_sub(s.submitted_ns);
                let action = s.action;
                self.record_latency(latency);
                Ok(Some(action))
            }
            Phase::AwaitingObs => Err(SessionError::Protocol(
                "take_action before submit_obs",
            )),
        }
    }

    /// Log reward earned since the last action.
    pub fn log_reward(
        &mut self,
        id: SessionId,
        reward: f32,
        now_ns: u64,
    ) -> Result<(), SessionError> {
        let s = self.session_mut(id)?;
        s.reward_acc += reward;
        s.episode_reward += reward as f64;
        s.last_activity_ns = now_ns;
        s.strikes = 0;
        Ok(())
    }

    /// Close the episode.  `final_obs` (when the client has one) becomes
    /// the terminal transition's next-observation; otherwise the last
    /// served observation is reused.  Returns the episode record.
    pub fn end_episode(
        &mut self,
        id: SessionId,
        final_obs: Option<&[f32]>,
        _now_ns: u64,
    ) -> Result<EpisodeRecord, SessionError> {
        let slot = id.slot as usize;
        // Validate before removing.
        self.session_mut(id)?;
        let mut s = self.sessions[slot].take().unwrap();
        if s.has_prev {
            let next = final_obs.unwrap_or(&s.obs);
            assert_eq!(next.len(), self.cfg.obs_dim, "gateway obs_dim mismatch");
            self.builder.add_transition(
                &s.obs,
                s.action.action,
                std::mem::take(&mut s.reward_acc),
                next,
                true,
            );
            self.stats.transitions += 1;
        }
        self.free.push(id.slot);
        self.stats.live_sessions -= 1;
        self.stats.completed += 1;
        let record =
            EpisodeRecord { reward: s.episode_reward, length: s.episode_len };
        self.episodes.push(record);
        Ok(record)
    }

    /// Write off idle clients: every live session silent past the idle
    /// deadline earns a strike; sessions past the forgiveness budget
    /// are reaped (slot freed, episode abandoned).  Returns the number
    /// reaped this pass.
    pub fn reap_idle(&mut self, now_ns: u64) -> usize {
        let deadline = self.cfg.idle_deadline_ns;
        let forgiveness = self.cfg.forgiveness;
        let mut reaped = 0;
        for slot in 0..self.sessions.len() {
            let reap = match &mut self.sessions[slot] {
                Some(s)
                    if now_ns.saturating_sub(s.last_activity_ns)
                        > deadline =>
                {
                    s.strikes += 1;
                    // Re-arm: a forgiven session gets a full deadline
                    // before its next strike, so "forgiveness" measures
                    // whole silent periods, not reap-pass frequency.
                    s.last_activity_ns = now_ns;
                    s.strikes > forgiveness
                }
                _ => false,
            };
            if reap {
                self.sessions[slot] = None;
                self.free.push(slot as u32);
                self.stats.live_sessions -= 1;
                self.stats.reaped += 1;
                reaped += 1;
            }
        }
        if reaped > 0 {
            // Drop reaped sessions' queued requests eagerly.
            self.pending.retain(|&slot| {
                matches!(
                    self.sessions.get(slot as usize).and_then(|s| s.as_ref()),
                    Some(s) if s.phase == Phase::Pending
                )
            });
        }
        reaped
    }

    /// Drain completed-episode records (metrics reporting).
    pub fn drain_episodes(&mut self) -> Vec<EpisodeRecord> {
        std::mem::take(&mut self.episodes)
    }

    /// Drain one experience fragment once at least `cfg.fragment`
    /// transitions have accumulated (None until then) — the source the
    /// train-from-gateway plan feeds to the replay service.
    pub fn drain_fragment(&mut self) -> Option<SampleBatch> {
        if self.builder.len() >= self.cfg.fragment.max(1) {
            Some(self.builder.build())
        } else {
            None
        }
    }

    /// Transitions buffered toward the next fragment.
    pub fn fragment_fill(&self) -> usize {
        self.builder.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::DummyPolicy;

    fn gw(max_sessions: usize) -> EpisodeGateway {
        EpisodeGateway::new(GatewayConfig {
            obs_dim: 4,
            max_sessions,
            idle_deadline_ns: 1_000,
            forgiveness: 1,
            fragment: 8,
        })
    }

    fn serve(g: &mut EpisodeGateway, p: &mut DummyPolicy, id: SessionId) -> i32 {
        g.submit_obs(id, &[0.5; 4], 10).unwrap();
        assert!(g.take_action(id, 11).unwrap().is_none(), "no tick yet");
        assert!(g.tick(p, 12) >= 1);
        g.take_action(id, 13).unwrap().expect("action ready").action
    }

    #[test]
    fn episode_protocol_round_trip() {
        let mut g = gw(8);
        let mut p = DummyPolicy::new(0.1);
        let id = g.start_episode(0).unwrap();
        for step in 0..5 {
            let a = serve(&mut g, &mut p, id);
            assert!(a == 0 || a == 1);
            g.log_reward(id, 1.0, 14 + step).unwrap();
        }
        let rec = g.end_episode(id, Some(&[0.0; 4]), 100).unwrap();
        assert_eq!(rec.length, 5);
        assert!((rec.reward - 5.0).abs() < 1e-9);
        assert_eq!(g.live_sessions(), 0);
        let eps = g.drain_episodes();
        assert_eq!(eps.len(), 1);
        assert_eq!(eps[0].length, 5);
    }

    #[test]
    fn tick_coalesces_pending_requests_into_one_batch() {
        let mut g = gw(8);
        let mut p = DummyPolicy::new(0.1);
        let ids: Vec<SessionId> =
            (0..5).map(|_| g.start_episode(0).unwrap()).collect();
        for (i, id) in ids.iter().enumerate() {
            g.submit_obs(*id, &[i as f32; 4], 10).unwrap();
        }
        assert_eq!(g.pending_requests(), 5);
        let fill = g.tick(&mut p, 20);
        assert_eq!(fill, 5, "all pending requests served by one forward");
        for id in &ids {
            assert!(g.take_action(*id, 30).unwrap().is_some());
        }
        let stats = g.stats();
        assert_eq!(stats.ticks, 1);
        assert_eq!(stats.batched_rows, 5);
        assert_eq!(stats.max_batch_fill, 5);
    }

    #[test]
    fn admission_watermark_sheds() {
        let mut g = gw(2);
        let a = g.start_episode(0).unwrap();
        let _b = g.start_episode(0).unwrap();
        assert_eq!(g.start_episode(0), Err(SessionError::Shed));
        assert_eq!(g.stats().shed, 1);
        // Ending one readmits.
        g.end_episode(a, None, 1).unwrap();
        assert!(g.start_episode(2).is_ok());
    }

    #[test]
    fn idle_sessions_reaped_through_forgiveness_ledger() {
        let mut g = gw(8);
        let id = g.start_episode(0).unwrap();
        // First pass past the deadline: strike, forgiven (ledger = 1).
        assert_eq!(g.reap_idle(2_000), 0);
        assert_eq!(g.live_sessions(), 1);
        // Second full silent period: past forgiveness, reaped.
        assert_eq!(g.reap_idle(4_000), 1);
        assert_eq!(g.live_sessions(), 0);
        assert_eq!(g.stats().reaped, 1);
        // The reaped session's id is fenced off.
        assert_eq!(
            g.submit_obs(id, &[0.0; 4], 5_000),
            Err(SessionError::Expired)
        );
    }

    #[test]
    fn activity_clears_the_ledger() {
        let mut g = gw(8);
        let id = g.start_episode(0).unwrap();
        assert_eq!(g.reap_idle(2_000), 0); // strike 1
        g.log_reward(id, 0.0, 2_500).unwrap(); // activity: ledger reset
        assert_eq!(g.reap_idle(4_000), 0); // strike 1 again, forgiven
        assert_eq!(g.live_sessions(), 1);
    }

    #[test]
    fn slot_reuse_fences_stale_ids() {
        let mut g = gw(2);
        let old = g.start_episode(0).unwrap();
        g.end_episode(old, None, 1).unwrap();
        let new = g.start_episode(2).unwrap();
        assert_eq!(old.slot, new.slot, "slot is reused");
        assert_ne!(old.nonce, new.nonce, "nonce is fresh");
        assert!(
            matches!(g.take_action(old, 3), Err(SessionError::Expired)),
            "stale id must not reach the new session"
        );
    }

    #[test]
    fn transitions_accumulate_and_drain_as_fragments() {
        let mut g = gw(8);
        let mut p = DummyPolicy::new(0.1);
        let id = g.start_episode(0).unwrap();
        // 8 served actions + rewards -> 7 intermediate transitions;
        // end_episode adds the terminal one -> fragment of 8.
        for _ in 0..8 {
            serve(&mut g, &mut p, id);
            g.log_reward(id, 2.0, 20).unwrap();
        }
        assert!(g.drain_fragment().is_none(), "7 < fragment while open");
        g.end_episode(id, None, 30).unwrap();
        let frag = g.drain_fragment().expect("terminal transition filled it");
        assert_eq!(frag.len(), 8);
        // Every transition carries the logged reward.
        assert!(frag.rewards.iter().all(|&r| (r - 2.0).abs() < 1e-6));
        assert_eq!(frag.dones.last().copied(), Some(1.0));
        assert_eq!(g.stats().transitions, 8);
    }

    #[test]
    fn protocol_violations_are_reported() {
        let mut g = gw(8);
        let mut p = DummyPolicy::new(0.1);
        let id = g.start_episode(0).unwrap();
        assert!(matches!(
            g.take_action(id, 1),
            Err(SessionError::Protocol(_))
        ));
        g.submit_obs(id, &[0.0; 4], 2).unwrap();
        assert!(matches!(
            g.submit_obs(id, &[0.0; 4], 3),
            Err(SessionError::Protocol(_))
        ));
        g.tick(&mut p, 4);
        g.take_action(id, 5).unwrap().unwrap();
    }

    #[test]
    fn p99_latency_tracks_slow_requests() {
        let mut g = gw(8);
        let mut p = DummyPolicy::new(0.1);
        let id = g.start_episode(0).unwrap();
        // 99 fast requests (1us), one slow (1ms).
        for i in 0..100u64 {
            g.submit_obs(id, &[0.0; 4], i * 10_000_000).unwrap();
            g.tick(&mut p, 0);
            let take_at = i * 10_000_000
                + if i == 50 { 1_000_000 } else { 1_000 };
            g.take_action(id, take_at).unwrap().unwrap();
        }
        let p99 = g.stats().p99_action_latency_us;
        assert!(p99 >= 999.0, "p99 should surface the slow request: {p99}");
    }

    #[test]
    fn reaped_pending_request_is_dropped_from_the_tick() {
        let mut g = gw(8);
        let mut p = DummyPolicy::new(0.1);
        let a = g.start_episode(0).unwrap();
        let b = g.start_episode(0).unwrap();
        g.submit_obs(a, &[0.0; 4], 10).unwrap();
        g.submit_obs(b, &[0.0; 4], 10).unwrap();
        // Session a goes silent past two deadlines; b stays active via
        // reward logging.
        g.log_reward(b, 0.0, 2_000).unwrap();
        g.reap_idle(2_000);
        g.log_reward(b, 0.0, 4_000).unwrap();
        assert_eq!(g.reap_idle(4_000), 1);
        assert_eq!(g.tick(&mut p, 5_000), 1, "only b's request survives");
        assert!(g.take_action(b, 6_000).unwrap().is_some());
        assert!(matches!(
            g.take_action(a, 6_000),
            Err(SessionError::Expired)
        ));
    }
}
