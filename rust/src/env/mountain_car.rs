//! MountainCar-v0 physics, ported from the classic Gym implementation
//! (Moore 1990 dynamics).  A second real control workload: sparse
//! reward (-1 per step until the goal), 3 actions, 200-step limit —
//! exercises the exploration-heavy DQN path far harder than CartPole.

use super::Env;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct MountainCar {
    position: f32,
    velocity: f32,
    steps: usize,
    done: bool,
    rng: Rng,
    max_steps: usize,
}

const MIN_POSITION: f32 = -1.2;
const MAX_POSITION: f32 = 0.6;
const MAX_SPEED: f32 = 0.07;
const GOAL_POSITION: f32 = 0.5;
const FORCE: f32 = 0.001;
const GRAVITY: f32 = 0.0025;

impl MountainCar {
    pub fn new(seed: u64) -> Self {
        super::note_env_constructed();
        let mut env = MountainCar {
            position: 0.0,
            velocity: 0.0,
            steps: 0,
            done: true,
            rng: Rng::new(seed),
            max_steps: 200,
        };
        env.reset();
        env
    }

    /// Advance the physics one step; returns (reward, done).
    fn advance(&mut self, action: i32) -> (f32, bool) {
        assert!(!self.done, "step() on done episode");
        assert!((0..3).contains(&action), "MountainCar action in 0..3");
        self.velocity += (action - 1) as f32 * FORCE
            - (3.0 * self.position).cos() * GRAVITY;
        self.velocity = self.velocity.clamp(-MAX_SPEED, MAX_SPEED);
        self.position = (self.position + self.velocity)
            .clamp(MIN_POSITION, MAX_POSITION);
        if self.position <= MIN_POSITION && self.velocity < 0.0 {
            self.velocity = 0.0;
        }
        self.steps += 1;
        let reached = self.position >= GOAL_POSITION;
        self.done = reached || self.steps >= self.max_steps;
        (-1.0, self.done)
    }
}

impl Env for MountainCar {
    fn obs_dim(&self) -> usize {
        2
    }

    fn num_actions(&self) -> usize {
        3
    }

    fn reset_into(&mut self, obs_out: &mut [f32]) {
        self.position = self.rng.uniform_range(-0.6, -0.4);
        self.velocity = 0.0;
        self.steps = 0;
        self.done = false;
        obs_out[0] = self.position;
        obs_out[1] = self.velocity;
    }

    fn step_into(&mut self, action: i32, obs_out: &mut [f32]) -> (f32, bool) {
        let out = self.advance(action);
        obs_out[0] = self.position;
        obs_out[1] = self.velocity;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reset_in_start_band_with_zero_velocity() {
        let mut env = MountainCar::new(0);
        let obs = env.reset();
        assert!((-0.6..-0.4).contains(&obs[0]));
        assert_eq!(obs[1], 0.0);
    }

    #[test]
    fn coasting_never_escapes_valley() {
        // Action 1 (no force): gravity alone cannot reach the goal.
        let mut env = MountainCar::new(1);
        env.reset();
        loop {
            let (obs, r, done) = env.step(1);
            assert_eq!(r, -1.0);
            if done {
                assert!(obs[0] < GOAL_POSITION);
                break;
            }
        }
    }

    #[test]
    fn oscillation_policy_reaches_goal() {
        // Classic energy-pumping: push in the direction of motion.
        let mut env = MountainCar::new(2);
        let mut obs = env.reset();
        for _ in 0..200 {
            let action = if obs[1] >= 0.0 { 2 } else { 0 };
            let (o, _, done) = env.step(action);
            obs = o;
            if done {
                break;
            }
        }
        assert!(
            obs[0] >= GOAL_POSITION,
            "energy pumping should solve it: pos={}",
            obs[0]
        );
    }

    #[test]
    fn velocity_stays_clamped() {
        let mut env = MountainCar::new(3);
        env.reset();
        for _ in 0..150 {
            let (obs, _, done) = env.step(2);
            assert!(obs[1].abs() <= MAX_SPEED + 1e-6);
            assert!((MIN_POSITION..=MAX_POSITION).contains(&obs[0]));
            if done {
                break;
            }
        }
    }

    #[test]
    fn step_limit_truncates() {
        let mut env = MountainCar::new(4);
        env.reset();
        let mut steps = 0;
        loop {
            let (_, _, done) = env.step(1);
            steps += 1;
            if done {
                break;
            }
        }
        assert_eq!(steps, 200);
    }
}
