//! Stubbed PJRT bindings — the API surface of the `xla` crate
//! (xla_extension) that [`crate::runtime`] programs against, gated for
//! builds without the native library.
//!
//! The offline build environment does not ship `libxla_extension`, so
//! this module provides the same types and signatures with every entry
//! point that would touch PJRT returning a "backend unavailable" error.
//! Code that never reaches the runtime (the whole dataflow layer, the
//! dummy-policy paths, all unit/property tests) is unaffected; XLA-backed
//! policies fail fast at client construction with a clear message.
//!
//! Swapping the real crate back in is mechanical: delete this module,
//! add the `xla` dependency, and drop the `use crate::xla;` imports in
//! `runtime/mod.rs` (the call sites are identical by construction).

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversion.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT backend unavailable (flowrl was built with the \
         stub xla module; install libxla_extension and swap in the real \
         `xla` crate to execute AOT artifacts)"
    )))
}

/// Element dtypes used by the artifact ABI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Primitive types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// A host-side tensor literal.
pub struct Literal(());

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal(())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A device-side buffer returned by execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// The PJRT client (CPU).  Not `Send` in the real crate; the stub keeps
/// that property so the one-runtime-per-actor-thread discipline stays
/// compiler-enforced.
pub struct PjRtClient {
    _not_send: std::marker::PhantomData<std::rc::Rc<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        let msg = format!("{err}");
        assert!(msg.contains("PJRT backend unavailable"), "{msg}");
    }

    #[test]
    fn scalar_literal_constructs_without_backend() {
        // Literal::scalar is infallible at the call site in runtime::run.
        let lit = Literal::scalar(1.5);
        assert!(lit.to_tuple().is_err());
    }
}
