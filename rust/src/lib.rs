//! # flowrl — RLlib Flow as a rust + JAX + Pallas stack
//!
//! A reproduction of *"RLlib Flow: Distributed Reinforcement Learning is a
//! Dataflow Problem"* (Liang et al., NeurIPS 2021): a hybrid
//! actor–dataflow programming model for distributed RL.
//!
//! The crate is organized as the paper's Figure 2:
//!
//! * [`iter`] — the general-purpose parallel-iterator library
//!   (`LocalIter`, `ParIter`, gather/union/split operators);
//! * [`ops`] — the RL-specific dataflow operators (`ParallelRollouts`,
//!   `TrainOneStep`, `Replay`, `StoreToReplayBuffer`, …);
//! * [`algorithms`] — the full algorithm suite expressed as dataflow
//!   plans (A2C, A3C, PPO, DQN, Ape-X, IMPALA, MAML, multi-agent union);
//! * [`baseline`] — low-level actor/RPC re-implementations (the paper's
//!   "original RLlib" comparison points) plus a Spark-Streaming-style
//!   microbatch executor for the Appendix A.1 comparison;
//! * substrates: [`actor`] (thread-per-actor runtime), [`env`] (CartPole
//!   family + the external-episode gateway),
//!   [`replay`] (prioritized replay over struct-of-arrays ring
//!   columns), [`sample_batch`], [`runtime`] (PJRT loader for the
//!   JAX/Pallas AOT artifacts), [`policy`] + [`rollout`] (XLA-backed
//!   policies and rollout workers), [`metrics`].
//!
//! ## The zero-copy experience path
//!
//! Experience batches are the items on every dataflow edge, so the data
//! layer is built for zero-copy steady-state operation:
//!
//! * [`sample_batch::SampleBatch`] columns are [`sample_batch::FCol`] /
//!   [`sample_batch::ICol`] — `Arc`-shared flat storage plus an
//!   (offset, len) window.  `slice` and `minibatches` return *views*
//!   that alias the parent's storage; `clone` is a reference-count bump;
//!   mutation is copy-on-write, so views never alias writes.
//! * `concat_all` sizes every output column exactly once and copies each
//!   input column once; `shuffle` builds a permutation index and gathers
//!   one time instead of per-element row swaps.
//! * The replay buffer stores transitions in preallocated
//!   struct-of-arrays ring columns and gathers samples into a reusable
//!   scratch batch (allocation-free once the learner keeps up).
//! * Weight broadcasts ship one `Arc<[f32]>` to all remotes instead of
//!   cloning the parameter vector per worker.
//!
//! ## The control plane
//!
//! Every dataflow edge is also at least one actor message, so the
//! control plane is built to disappear from the per-item path (see
//! `docs/actor_runtime.md`):
//!
//! * Actors run on **bounded ring mailboxes** with 256-byte inline
//!   envelopes: a steady-state `cast`/`call`/`call_into` is a slot
//!   write — zero per-message heap allocation (asserted by
//!   `tests/actor_alloc.rs`), with blocking-send/`try_cast`
//!   backpressure instead of unbounded queue growth.
//! * The sequencing operators (`gather_async`, `gather_sync`) and
//!   `union`'s async mode share one bounded [`actor::CompletionQueue`]
//!   (the batched-`ray.wait` analog), making `num_async` and
//!   `Union::buffer` real flow-control knobs.
//! * Actors are **supervised**: a panic poisons the actor instead of
//!   tearing down the driver — pending replies resolve to
//!   [`actor::ActorDied`], gathers retire the dead shard and keep
//!   streaming, and `WorkerSet::restart_dead` respawns poisoned rollout
//!   workers from the retained factory.
//! * The control plane is **elastic**: gathers resolve shard index ->
//!   handle through a versioned [`actor::ShardRegistry`] on every
//!   dispatch, so a restarted worker rejoins *running* plans live (no
//!   rebuild), with epoch-tagged completions keeping dead incarnations'
//!   late results and death notices from touching their replacements
//!   (`tests/elastic.rs`).
//! * Weight broadcasts are **versioned casts** with a drop-oldest
//!   eviction policy ([`actor::WeightCaster`]): at most one queued
//!   apply per worker, superseded versions coalesce into it, and a
//!   worker whose mailbox depth exceeds the watermark is shed instead
//!   of stalling the learner.
//! * Per-actor telemetry (queue depth/high-water, messages, busy/idle
//!   time) flows through a global registry into every
//!   `TrainResult::actor_stats`, so each report can say *where* the
//!   pipeline is starved (`TrainResult::pipeline_summary`).
//! * Failure handling is **scripted and supervised**: a process-global
//!   fault-injection plane ([`actor::faults`] — seeded, deterministic
//!   failpoints at the control plane's hot sites, one relaxed atomic
//!   load when disarmed) turns crashes, hangs, delays, and lost
//!   messages into scripted events; *deadline supervision*
//!   (`gather_*_deadline`) writes off a shard whose dispatches go
//!   silent, force-kills the wedge into the normal poison path, and
//!   degrades to the surviving quorum; and
//!   `WorkerSet::restart_dead_with_policy` recovers corpses under
//!   exponential backoff with a per-slot budget and a circuit breaker
//!   that tombstones crash-looping slots (`tests/faults.rs`,
//!   `TrainResult::faults`).
//! * The elasticity loop is **closed**: membership is dynamic
//!   (`WorkerSet::scale_to` grows/shrinks a *running* plan, single- and
//!   multi-agent alike) and an [`actor::Autoscaler`] feedback
//!   controller decides *when* — sampling the telemetry each report
//!   and driving `scale_to` with deadband/confirmation/cooldown
//!   hysteresis (`ops::Reporting::autoscale`, `tests/autoscale.rs`).
//! * Experience is **durable on demand**: [`offline::EpisodeLogWriter`]
//!   taps rollout workers and gateway shards to persist fragments as
//!   CRC-framed binary segments (one shared codec,
//!   [`sample_batch::wire`], under checkpoints and logs alike), and
//!   [`offline::LogStreamReader`] tail-follows them as just another
//!   dataflow source — `ops::read_from_logs` feeds the replay service
//!   from historic logs, `algorithms::offline_dqn_plan` trains with
//!   zero envs constructed, and `ops::ope_estimate` scores policies
//!   against recorded traffic by importance sampling (`docs/offline.md`,
//!   `tests/offline.rs`).
//! * The env boundary is **invertible**: [`env::EpisodeGateway`] +
//!   [`ops::GatewayService`] serve policies to *client-owned* envs —
//!   concurrent external episodes live in elastic session-table shards
//!   (admission watermarks, idle-deadline reaping, lease-fenced
//!   sessions), pending requests coalesce into one batched forward per
//!   tick, and gateway backlog is the third autoscaled axis;
//!   `algorithms::gateway_dqn_plan` trains from the experience served
//!   episodes leave behind (`docs/gateway.md`, `tests/gateway.rs`).
//!
//! Numerics are JAX/Pallas programs lowered once to HLO text
//! (`make artifacts`) and executed from rust via PJRT — python is never
//! on the training path.  In offline builds the PJRT bindings are the
//! gated stub in [`xla`]; the dataflow layer and all dummy-policy paths
//! run without it.

pub mod actor;
pub mod algorithms;
pub mod baseline;
pub mod checkpoint;
pub mod env;
pub mod iter;
pub mod metrics;
pub mod offline;
pub mod ops;
pub mod policy;
pub mod replay;
pub mod rollout;
pub mod runtime;
pub mod sample_batch;
pub mod util;
pub mod xla;

pub use sample_batch::SampleBatch;
