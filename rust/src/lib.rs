//! # flowrl — RLlib Flow as a rust + JAX + Pallas stack
//!
//! A reproduction of *"RLlib Flow: Distributed Reinforcement Learning is a
//! Dataflow Problem"* (Liang et al., NeurIPS 2021): a hybrid
//! actor–dataflow programming model for distributed RL.
//!
//! The crate is organized as the paper's Figure 2:
//!
//! * [`iter`] — the general-purpose parallel-iterator library
//!   (`LocalIter`, `ParIter`, gather/union/split operators);
//! * [`ops`] — the RL-specific dataflow operators (`ParallelRollouts`,
//!   `TrainOneStep`, `Replay`, `StoreToReplayBuffer`, …);
//! * [`algorithms`] — the full algorithm suite expressed as dataflow
//!   plans (A2C, A3C, PPO, DQN, Ape-X, IMPALA, MAML, multi-agent union);
//! * [`baseline`] — low-level actor/RPC re-implementations (the paper's
//!   "original RLlib" comparison points) plus a Spark-Streaming-style
//!   microbatch executor for the Appendix A.1 comparison;
//! * substrates: [`actor`] (tokio actor runtime), [`env`] (CartPole
//!   family), [`replay`] (prioritized replay), [`sample_batch`],
//!   [`runtime`] (PJRT loader for the JAX/Pallas AOT artifacts),
//!   [`policy`] + [`rollout`] (XLA-backed policies and rollout workers),
//!   [`metrics`].
//!
//! Numerics are JAX/Pallas programs lowered once to HLO text
//! (`make artifacts`) and executed from rust via PJRT — python is never
//! on the training path.

pub mod actor;
pub mod algorithms;
pub mod baseline;
pub mod checkpoint;
pub mod env;
pub mod iter;
pub mod metrics;
pub mod ops;
pub mod policy;
pub mod replay;
pub mod rollout;
pub mod runtime;
pub mod sample_batch;
pub mod util;

pub use sample_batch::SampleBatch;
