//! Training metrics: per-episode stats collected by rollout workers and
//! aggregated by the `StandardMetricsReporting` dataflow operator.

use std::collections::BTreeMap;

use crate::actor::{
    ActorStatsSnapshot, AutoscaleStats, FaultStats, WeightCastStats,
};
use crate::env::GatewayBacklogStats;
use crate::offline::OfflineLogStats;
use crate::replay::ReplayBacklogStats;
use crate::rollout::ScaleStats;
use crate::util::MovingStat;

/// A finished episode, reported by the worker that ran it.
#[derive(Debug, Clone, Copy)]
pub struct EpisodeRecord {
    pub reward: f64,
    pub length: usize,
}

/// Rolling aggregation of episodes + counters, one per trainer.
#[derive(Debug)]
pub struct MetricsHub {
    episode_rewards: MovingStat,
    episode_lengths: MovingStat,
    pub num_env_steps_sampled: u64,
    pub num_env_steps_trained: u64,
    pub num_grad_updates: u64,
    start: std::time::Instant,
    /// Last scalar training stats (loss etc.), merged per key.
    pub learner_stats: BTreeMap<String, f64>,
}

impl Default for MetricsHub {
    fn default() -> Self {
        Self::new(100)
    }
}

impl MetricsHub {
    pub fn new(window: usize) -> Self {
        MetricsHub {
            episode_rewards: MovingStat::new(window),
            episode_lengths: MovingStat::new(window),
            num_env_steps_sampled: 0,
            num_env_steps_trained: 0,
            num_grad_updates: 0,
            start: std::time::Instant::now(),
            learner_stats: BTreeMap::new(),
        }
    }

    pub fn record_episodes(&mut self, episodes: &[EpisodeRecord]) {
        for e in episodes {
            self.episode_rewards.push(e.reward);
            self.episode_lengths.push(e.length as f64);
        }
    }

    pub fn record_learner_stat(&mut self, key: &str, value: f64) {
        self.learner_stats.insert(key.to_string(), value);
    }

    /// Snapshot for reporting (the item type of metric streams).
    pub fn snapshot(&self) -> TrainResult {
        TrainResult {
            episode_reward_mean: self.episode_rewards.mean(),
            episode_len_mean: self.episode_lengths.mean(),
            episodes_total: self.episode_rewards.lifetime_count(),
            num_env_steps_sampled: self.num_env_steps_sampled,
            num_env_steps_trained: self.num_env_steps_trained,
            num_grad_updates: self.num_grad_updates,
            sampled_steps_per_s: self.num_env_steps_sampled as f64
                / self.start.elapsed().as_secs_f64().max(1e-9),
            learner_stats: self.learner_stats.clone(),
            // Filled by the reporting operator from the actor registry.
            actor_stats: Vec::new(),
            weight_casts: None,
            scale: None,
            autoscale: None,
            faults: None,
            replay: None,
            replay_autoscale: None,
            gateway: None,
            gateway_autoscale: None,
            offline: None,
        }
    }
}

/// The item emitted by `StandardMetricsReporting` — RLlib's train result
/// dict, typed.
#[derive(Debug, Clone, Default)]
pub struct TrainResult {
    pub episode_reward_mean: f64,
    pub episode_len_mean: f64,
    pub episodes_total: u64,
    pub num_env_steps_sampled: u64,
    pub num_env_steps_trained: u64,
    pub num_grad_updates: u64,
    pub sampled_steps_per_s: f64,
    pub learner_stats: BTreeMap<String, f64>,
    /// Runtime telemetry for every live actor at report time (queue
    /// depth + high water, messages, busy/idle ns, supervision state) —
    /// filled by the metrics-reporting operators from the actor
    /// registry.  `utilization()` per entry locates the starved stage.
    pub actor_stats: Vec<ActorStatsSnapshot>,
    /// Weight-broadcast eviction counters (versions published, applies
    /// enqueued, superseded casts coalesced, overloaded/stale casts
    /// shed) — filled by `ops::Reporting` from the
    /// `WorkerSet`'s `WeightCaster`.  `None` for reporting paths
    /// without one.
    pub weight_casts: Option<WeightCastStats>,
    /// Elastic scale events (workers added/removed over the set's
    /// lifetime, current live membership vs registry slots) — filled by
    /// `ops::Reporting` from the `WorkerSet`.  `None` for
    /// reporting paths without one.
    pub scale: Option<ScaleStats>,
    /// Autoscaling-controller decision counters (directives issued,
    /// holds by deadband/confirmation/cooldown, failed applies, last
    /// target) — filled by `ops::Reporting::autoscale` when an
    /// `actor::Autoscaler` drives the set.  `None` on manually scaled
    /// plans.
    pub autoscale: Option<AutoscaleStats>,
    /// Fault-supervision counters (shards declared suspect by deadline
    /// supervision, forced restarts applied by the restart policy,
    /// circuit-breaker trips that tombstoned a crash-looping slot) —
    /// filled by the metrics-reporting operators from the `WorkerSet`'s
    /// `FaultCounters`.  `None` for reporting paths without one.
    pub faults: Option<FaultStats>,
    /// Replay-tier backlog telemetry (live shards, deepest mailbox,
    /// ring fill, store/sample/not-ready traffic, priority-update
    /// applies vs discards) — filled by `ops::Reporting::replay` from
    /// the plan's `ops::ReplayService`.  `None` on plans without a
    /// replay tier.
    pub replay: Option<ReplayBacklogStats>,
    /// Decision counters of the autoscaler driving the **replay-shard
    /// pool** (distinct from `autoscale`, which describes the sampler
    /// pool's controller).  `None` when replay shards are manually
    /// scaled.
    pub replay_autoscale: Option<AutoscaleStats>,
    /// External-episode gateway telemetry (live shards, sessions held,
    /// pending action requests, p99 action latency, admission sheds,
    /// batch fill) — filled by reporting paths wired to an
    /// `ops::GatewayService`.  `None` on plans without a gateway tier.
    pub gateway: Option<GatewayBacklogStats>,
    /// Decision counters of the autoscaler driving the
    /// **gateway-shard pool**.  `None` when gateway shards are
    /// manually scaled.
    pub gateway_autoscale: Option<AutoscaleStats>,
    /// Offline log-ingestion telemetry (streams followed, frames/
    /// transitions/bytes decoded, corrupt + truncated frames, reader
    /// lag, interval decode rate) — filled by
    /// `ops::Reporting::offline` from the plan's shared
    /// `offline::OfflineCounters`.  `None` on plans without a log
    /// source.
    pub offline: Option<OfflineLogStats>,
}

impl TrainResult {
    /// One-line pipeline-health summary: busiest and idlest actor by
    /// utilization, plus the deepest mailbox high-water mark.
    pub fn pipeline_summary(&self) -> String {
        let mut live: Vec<&ActorStatsSnapshot> = self
            .actor_stats
            .iter()
            .filter(|s| s.busy_ns + s.idle_ns > 0)
            .collect();
        if live.is_empty() {
            return "no actor telemetry".to_string();
        }
        live.sort_by(|a, b| {
            a.utilization().total_cmp(&b.utilization())
        });
        let idle = live.first().unwrap();
        let busy = live.last().unwrap();
        let hwm = self
            .actor_stats
            .iter()
            .max_by_key(|s| s.queue_hwm)
            .unwrap();
        let dead = self.actor_stats.iter().filter(|s| s.poisoned).count();
        let mut out = format!(
            "busiest={}({:.0}%) idlest={}({:.0}%) deepest_queue={}({}) dead={}",
            busy.name,
            busy.utilization() * 100.0,
            idle.name,
            idle.utilization() * 100.0,
            hwm.name,
            hwm.queue_hwm,
            dead,
        );
        if let Some(wc) = &self.weight_casts {
            out.push_str(&format!(
                " weight_casts=v{}(enq={} coalesced={} shed={} stale={})",
                wc.version, wc.enqueued, wc.coalesced, wc.shed, wc.shed_stale
            ));
        }
        if let Some(sc) = &self.scale {
            out.push_str(&format!(
                " scale={}/{}slots(+{} -{})",
                sc.live, sc.slots, sc.added, sc.removed
            ));
        }
        if let Some(a) = &self.autoscale {
            out.push_str(&format!(
                " autoscale=t{}(up={} down={} hold={} fail={})",
                a.last_target,
                a.decisions_up,
                a.decisions_down,
                a.held_deadband + a.held_confirm + a.held_cooldown,
                a.failed,
            ));
        }
        if let Some(ft) = &self.faults {
            if *ft != FaultStats::default() {
                out.push_str(&format!(
                    " faults=s{}/r{}/b{}",
                    ft.suspects, ft.forced_restarts, ft.breaker_trips
                ));
            }
        }
        if let Some(rp) = &self.replay {
            out.push_str(&format!(
                " replay={}shards(fill={:.0}% q={} store={} sample={} \
                 prio={}+{}-)",
                rp.live_shards,
                rp.max_ring_fill * 100.0,
                rp.max_queue_hwm,
                rp.stores,
                rp.samples,
                rp.priority_applied,
                rp.priority_discarded,
            ));
        }
        if let Some(a) = &self.replay_autoscale {
            out.push_str(&format!(
                " replay_autoscale=t{}(up={} down={} hold={} fail={})",
                a.last_target,
                a.decisions_up,
                a.decisions_down,
                a.held_deadband + a.held_confirm + a.held_cooldown,
                a.failed,
            ));
        }
        if let Some(gw) = &self.gateway {
            out.push_str(&format!(
                " gateway={}shards(sess={} pend={} p99={:.0}us shed={} \
                 fill={})",
                gw.live_shards,
                gw.sessions,
                gw.pending,
                gw.p99_action_latency_us,
                gw.shed,
                gw.max_batch_fill,
            ));
        }
        if let Some(a) = &self.gateway_autoscale {
            out.push_str(&format!(
                " gateway_autoscale=t{}(up={} down={} hold={} fail={})",
                a.last_target,
                a.decisions_up,
                a.decisions_down,
                a.held_deadband + a.held_confirm + a.held_cooldown,
                a.failed,
            ));
        }
        if let Some(o) = &self.offline {
            out.push_str(&format!(
                " offline={}streams(frames={} @{:.0}/s lag={}B corrupt={} \
                 torn={})",
                o.streams,
                o.frames,
                o.frames_per_s,
                o.lag_bytes,
                o.corrupt_frames,
                o.truncated_tails,
            ));
        }
        out
    }
}

impl std::fmt::Display for TrainResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "reward_mean={:8.2} len_mean={:6.1} episodes={:5} sampled={:8} \
             trained={:8} updates={:6} steps/s={:9.0}",
            self.episode_reward_mean,
            self.episode_len_mean,
            self.episodes_total,
            self.num_env_steps_sampled,
            self.num_env_steps_trained,
            self.num_grad_updates,
            self.sampled_steps_per_s,
        )?;
        if let Some(loss) = self.learner_stats.get("loss") {
            write!(f, " loss={loss:9.4}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates_episodes() {
        let mut hub = MetricsHub::new(10);
        hub.record_episodes(&[
            EpisodeRecord { reward: 10.0, length: 10 },
            EpisodeRecord { reward: 20.0, length: 20 },
        ]);
        hub.num_env_steps_sampled = 30;
        let snap = hub.snapshot();
        assert_eq!(snap.episode_reward_mean, 15.0);
        assert_eq!(snap.episode_len_mean, 15.0);
        assert_eq!(snap.episodes_total, 2);
        assert_eq!(snap.num_env_steps_sampled, 30);
    }

    #[test]
    fn window_bounds_reward_mean() {
        let mut hub = MetricsHub::new(2);
        for r in [1.0, 2.0, 3.0, 4.0] {
            hub.record_episodes(&[EpisodeRecord { reward: r, length: 1 }]);
        }
        assert_eq!(hub.snapshot().episode_reward_mean, 3.5);
        assert_eq!(hub.snapshot().episodes_total, 4);
    }

    #[test]
    fn pipeline_summary_names_extremes() {
        let mut r = TrainResult::default();
        assert_eq!(r.pipeline_summary(), "no actor telemetry");
        r.actor_stats = vec![
            ActorStatsSnapshot {
                name: "sampler".into(),
                busy_ns: 90,
                idle_ns: 10,
                queue_hwm: 3,
                ..Default::default()
            },
            ActorStatsSnapshot {
                name: "learner".into(),
                busy_ns: 10,
                idle_ns: 90,
                queue_hwm: 17,
                poisoned: false,
                ..Default::default()
            },
        ];
        let s = r.pipeline_summary();
        assert!(s.contains("busiest=sampler(90%)"), "{s}");
        assert!(s.contains("idlest=learner(10%)"), "{s}");
        assert!(s.contains("deepest_queue=learner(17)"), "{s}");
        assert!(s.contains("dead=0"), "{s}");
        assert!(!s.contains("scale="), "no scale section without stats");
        r.scale = Some(ScaleStats { added: 3, removed: 1, live: 4, slots: 5 });
        let s = r.pipeline_summary();
        assert!(s.contains("scale=4/5slots(+3 -1)"), "{s}");
        assert!(!s.contains("autoscale="), "no section without a controller");
        r.autoscale = Some(AutoscaleStats {
            reports: 9,
            decisions_up: 2,
            decisions_down: 1,
            held_deadband: 3,
            held_confirm: 2,
            held_cooldown: 1,
            failed: 0,
            last_target: 4,
        });
        let s = r.pipeline_summary();
        assert!(
            s.contains("autoscale=t4(up=2 down=1 hold=6 fail=0)"),
            "{s}"
        );
        // All-zero fault stats stay silent; nonzero ones render.
        r.faults = Some(FaultStats::default());
        assert!(!r.pipeline_summary().contains("faults="));
        r.faults = Some(FaultStats {
            suspects: 2,
            forced_restarts: 3,
            breaker_trips: 1,
        });
        let s = r.pipeline_summary();
        assert!(s.contains("faults=s2/r3/b1"), "{s}");
        // Replay tier sections.
        assert!(!s.contains("replay="), "no replay section without stats");
        r.replay = Some(ReplayBacklogStats {
            live_shards: 3,
            max_ring_fill: 0.5,
            max_queue_hwm: 7,
            stores: 40,
            samples: 25,
            priority_applied: 24,
            priority_discarded: 1,
            ..Default::default()
        });
        r.replay_autoscale = Some(AutoscaleStats {
            decisions_up: 1,
            held_deadband: 5,
            last_target: 3,
            ..Default::default()
        });
        let s = r.pipeline_summary();
        assert!(
            s.contains(
                "replay=3shards(fill=50% q=7 store=40 sample=25 prio=24+1-)"
            ),
            "{s}"
        );
        assert!(
            s.contains("replay_autoscale=t3(up=1 down=0 hold=5 fail=0)"),
            "{s}"
        );
        // Gateway tier sections.
        assert!(!s.contains("gateway="), "no gateway section without stats");
        r.gateway = Some(GatewayBacklogStats {
            live_shards: 2,
            sessions: 12,
            pending: 3,
            p99_action_latency_us: 250.4,
            shed: 5,
            max_batch_fill: 6,
            ..Default::default()
        });
        r.gateway_autoscale = Some(AutoscaleStats {
            decisions_up: 2,
            held_confirm: 4,
            last_target: 2,
            ..Default::default()
        });
        let s = r.pipeline_summary();
        assert!(
            s.contains(
                "gateway=2shards(sess=12 pend=3 p99=250us shed=5 fill=6)"
            ),
            "{s}"
        );
        assert!(
            s.contains("gateway_autoscale=t2(up=2 down=0 hold=4 fail=0)"),
            "{s}"
        );
        // Offline log-ingestion section.
        assert!(!s.contains("offline="), "no offline section without stats");
        r.offline = Some(OfflineLogStats {
            streams: 2,
            frames: 120,
            frames_per_s: 35.0,
            lag_bytes: 4096,
            corrupt_frames: 1,
            truncated_tails: 2,
            ..Default::default()
        });
        let s = r.pipeline_summary();
        assert!(
            s.contains(
                "offline=2streams(frames=120 @35/s lag=4096B corrupt=1 \
                 torn=2)"
            ),
            "{s}"
        );
    }

    #[test]
    fn learner_stats_merge_by_key() {
        let mut hub = MetricsHub::new(4);
        hub.record_learner_stat("loss", 1.0);
        hub.record_learner_stat("loss", 0.5);
        assert_eq!(hub.snapshot().learner_stats["loss"], 0.5);
    }
}
