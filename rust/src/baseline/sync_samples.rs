//! A2C/PPO the low-level way — RLlib's original `SyncSamplesOptimizer`:
//! manual barrier rounds of `sample.remote()`, driver-side concat,
//! learn on the local worker, manual weight broadcast.

use crate::metrics::{MetricsHub, TrainResult};
use crate::rollout::WorkerSet;
use crate::sample_batch::SampleBatch;
use crate::util::TimerStat;

pub struct SyncSamplesOptimizer {
    workers: WorkerSet,
    train_batch_size: usize,

    sample_timer: TimerStat,
    grad_timer: TimerStat,
    sync_timer: TimerStat,

    num_steps_sampled: usize,
    num_steps_trained: usize,
    hub: MetricsHub,
}

impl SyncSamplesOptimizer {
    pub fn new(workers: WorkerSet, train_batch_size: usize) -> Self {
        SyncSamplesOptimizer {
            workers,
            train_batch_size,
            sample_timer: TimerStat::new(),
            grad_timer: TimerStat::new(),
            sync_timer: TimerStat::new(),
            num_steps_sampled: 0,
            num_steps_trained: 0,
            hub: MetricsHub::new(100),
        }
    }

    pub fn step(&mut self) -> TrainResult {
        // Broadcast current weights before sampling (sync semantics).
        self.sync_timer.time(|| {
            self.workers.sync_weights();
        });

        // Collect samples until the train batch size is reached.
        let mut collected: Vec<SampleBatch> = Vec::new();
        let mut count = 0usize;
        while count < self.train_batch_size {
            let round = self.sample_timer.time(|| {
                let replies: Vec<_> = self
                    .workers
                    .remotes()
                    .iter()
                    .map(|w| w.call_deferred(|state| state.sample()))
                    .collect();
                replies
                .into_iter()
                .map(|r| r.recv().expect("worker died"))
                .collect::<Vec<_>>()
            });
            for b in round {
                count += b.len();
                collected.push(b);
            }
        }
        let train_batch = SampleBatch::concat_all(&collected);
        self.num_steps_sampled += train_batch.len();

        // One (or, for PPO policies, several epochs of) sgd step(s).
        let steps = train_batch.len();
        let stats = self.grad_timer.time(|| {
            self.workers
                .local
                .call(move |w| w.learn_on_batch(&train_batch))
                .expect("learner died")
        });
        self.num_steps_trained += steps;

        self.hub.num_env_steps_trained = self.num_steps_trained as u64;
        self.hub.num_grad_updates += 1;
        for (k, v) in stats {
            self.hub.record_learner_stat(&k, v);
        }
        let (episodes, sampled) = self.workers.collect_metrics();
        self.hub.record_episodes(&episodes);
        self.hub.num_env_steps_sampled += sampled as u64;
        self.hub.snapshot()
    }

    pub fn timer_report(&self) -> String {
        format!(
            "sample={:?} grad={:?} sync={:?}",
            self.sample_timer.mean(),
            self.grad_timer.mean(),
            self.sync_timer.mean()
        )
    }
}
