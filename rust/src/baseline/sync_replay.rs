//! DQN the low-level way — RLlib's original `SyncReplayOptimizer`:
//! sample, push to a driver-owned buffer, replay, learn, manual
//! priority updates and target-network bookkeeping.

use crate::metrics::{MetricsHub, TrainResult};
use crate::replay::PrioritizedReplayBuffer;
use crate::rollout::WorkerSet;
use crate::util::TimerStat;

pub struct SyncReplayOptimizer {
    workers: WorkerSet,
    buffer: PrioritizedReplayBuffer,
    learning_starts: usize,
    train_batch_size: usize,
    target_update_every: usize,

    sample_timer: TimerStat,
    replay_timer: TimerStat,
    grad_timer: TimerStat,

    num_steps_sampled: usize,
    num_steps_trained: usize,
    steps_since_target: usize,
    hub: MetricsHub,
}

impl SyncReplayOptimizer {
    pub fn new(
        workers: WorkerSet,
        buffer_capacity: usize,
        learning_starts: usize,
        train_batch_size: usize,
        target_update_every: usize,
    ) -> Self {
        let obs_dim =
            workers.local.call(|w| w.obs_dim()).expect("learner died");
        SyncReplayOptimizer {
            workers,
            buffer: PrioritizedReplayBuffer::with_obs_dim(
                buffer_capacity,
                obs_dim,
                0.6,
                0.4,
                1,
            ),
            learning_starts,
            train_batch_size,
            target_update_every,
            sample_timer: TimerStat::new(),
            replay_timer: TimerStat::new(),
            grad_timer: TimerStat::new(),
            num_steps_sampled: 0,
            num_steps_trained: 0,
            steps_since_target: 0,
            hub: MetricsHub::new(100),
        }
    }

    pub fn step(&mut self) -> TrainResult {
        // (1) Sample one round from every worker into the buffer.
        let round = self.sample_timer.time(|| {
            let replies: Vec<_> = self
                .workers
                .remotes()
                .iter()
                .map(|w| w.call_deferred(|state| state.sample()))
                .collect();
            replies
                .into_iter()
                .map(|r| r.recv().expect("worker died"))
                .collect::<Vec<_>>()
        });
        for batch in round {
            self.num_steps_sampled += batch.len();
            self.buffer.add_batch(&batch);
        }

        // (2) Replay + learn, once past learning_starts.
        if self.num_steps_sampled >= self.learning_starts {
            let sample = self.replay_timer.time(|| {
                self.buffer.sample(self.train_batch_size)
            });
            if let Some(sample) = sample {
                let steps = sample.batch.len();
                let indices = sample.indices;
                let batch = sample.batch;
                let (stats, td) = self.grad_timer.time(|| {
                    self.workers
                        .local
                        .call(move |w| w.learn_and_td(&batch))
                        .expect("learner died")
                });
                self.buffer.update_priorities(&indices, &td);
                self.num_steps_trained += steps;
                self.steps_since_target += steps;
                for (k, v) in stats {
                    self.hub.record_learner_stat(&k, v);
                }
                self.hub.num_grad_updates += 1;

                // (3) Push fresh weights to the exploration workers.
                self.workers.sync_weights();

                // (4) Periodic target-network sync.
                if self.steps_since_target >= self.target_update_every {
                    self.steps_since_target = 0;
                    self.workers.local.cast(|w| w.policy.update_target());
                }
            }
        }

        self.hub.num_env_steps_trained = self.num_steps_trained as u64;
        let (episodes, sampled) = self.workers.collect_metrics();
        self.hub.record_episodes(&episodes);
        self.hub.num_env_steps_sampled += sampled as u64;
        self.hub.snapshot()
    }

    pub fn timer_report(&self) -> String {
        format!(
            "sample={:?} replay={:?} grad={:?}",
            self.sample_timer.mean(),
            self.replay_timer.mean(),
            self.grad_timer.mean()
        )
    }
}
