//! IMPALA the low-level way — an async sample/learn pipeline with an
//! explicit completion queue and manual weight pushes (the structure of
//! RLlib's original IMPALA implementation's aggregation path, minus the
//! multi-level aggregation tree).  Baseline for Fig. 13b.

use std::collections::HashMap;

use crate::actor::{Completion, CompletionQueue};
use crate::algorithms::assemble_time_major_into;
use crate::metrics::{MetricsHub, TrainResult};
use crate::policy::ImpalaBatch;
use crate::rollout::WorkerSet;
use crate::sample_batch::SampleBatch;
use crate::util::TimerStat;

pub struct AsyncPipelineOptimizer {
    workers: WorkerSet,
    t_len: usize,
    b_lanes: usize,
    queue_depth: usize,

    samples: CompletionQueue<SampleBatch>,
    tags: HashMap<usize, usize>,
    next_tag: usize,
    /// Recycled time-major learner batch (rides to the learner actor
    /// and back with each call).
    tb_scratch: ImpalaBatch,

    wait_timer: TimerStat,
    learn_timer: TimerStat,

    num_steps_sampled: usize,
    num_steps_trained: usize,
    hub: MetricsHub,
    started: bool,
}

impl AsyncPipelineOptimizer {
    pub fn new(
        workers: WorkerSet,
        t_len: usize,
        b_lanes: usize,
        queue_depth: usize,
    ) -> Self {
        let samples = CompletionQueue::bounded(
            (workers.num_remotes() * queue_depth).max(1),
        );
        AsyncPipelineOptimizer {
            workers,
            t_len,
            b_lanes,
            queue_depth,
            samples,
            tags: HashMap::new(),
            next_tag: 0,
            tb_scratch: ImpalaBatch::default(),
            wait_timer: TimerStat::new(),
            learn_timer: TimerStat::new(),
            num_steps_sampled: 0,
            num_steps_trained: 0,
            hub: MetricsHub::new(100),
            started: false,
        }
    }

    fn launch(&mut self, worker_idx: usize) {
        // Tombstoned slot (scale-down): nothing to relaunch, no panic.
        let Some(worker) = self.workers.remote(worker_idx) else {
            return;
        };
        let tag = self.next_tag;
        self.next_tag += 1;
        worker.call_into(tag, &self.samples, |w| w.sample());
        self.tags.insert(tag, worker_idx);
    }

    fn start(&mut self) {
        let weights: std::sync::Arc<[f32]> = self
            .workers
            .local
            .call(|w| w.get_weights())
            .expect("learner died")
            .into();
        for idx in 0..self.workers.num_remotes() {
            let Some(worker) = self.workers.remote(idx) else {
                continue; // tombstoned slot
            };
            let w = std::sync::Arc::clone(&weights);
            worker.cast(move |state| state.set_weights(&w));
            for _ in 0..self.queue_depth {
                self.launch(idx);
            }
        }
        self.started = true;
    }

    /// One learner step: wait for a fragment, V-trace learn, push
    /// weights back to the producing worker, relaunch its task.
    pub fn step(&mut self) -> TrainResult {
        if !self.started {
            self.start();
        }
        let samples = self.samples.clone();
        let (tag, batch) = self.wait_timer.time(|| match samples.pop() {
            Completion::Item { tag, value } => (tag, value),
            Completion::Dropped { tag } => panic!("worker for {tag} died"),
        });
        let worker_idx = self.tags.remove(&tag).expect("unknown tag");
        let steps = batch.len();
        self.num_steps_sampled += steps;

        let mut tb = std::mem::take(&mut self.tb_scratch);
        assemble_time_major_into(&batch, self.t_len, self.b_lanes, &mut tb);
        let (stats, weights, tb_back) = self.learn_timer.time(|| {
            self.workers
                .local
                .call(move |w| {
                    let stats = w.policy.learn_impala(&tb);
                    (stats, w.get_weights(), tb)
                })
                .expect("learner died")
        });
        self.tb_scratch = tb_back;
        self.num_steps_trained += steps;

        if let Some(worker) = self.workers.remote(worker_idx) {
            worker.cast(move |w| w.set_weights(&weights));
        }
        self.launch(worker_idx);

        self.hub.num_env_steps_trained = self.num_steps_trained as u64;
        self.hub.num_grad_updates += 1;
        for (k, v) in stats {
            self.hub.record_learner_stat(&k, v);
        }
        let (episodes, sampled) = self.workers.collect_metrics();
        self.hub.record_episodes(&episodes);
        self.hub.num_env_steps_sampled += sampled as u64;
        self.hub.snapshot()
    }

    pub fn timer_report(&self) -> String {
        format!(
            "wait={:?} learn={:?}",
            self.wait_timer.mean(),
            self.learn_timer.mean()
        )
    }
}
