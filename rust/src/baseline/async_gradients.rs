//! A3C the low-level way — a direct port of the paper's Listing A2
//! ("a small portion of the RLlib A3C policy optimizer"): explicit
//! pending-gradient map, wait-for-one completion loop, manual weight
//! put/get, per-phase timers.  Compare with `algorithms::a3c_plan`
//! (11 lines of plan) — this file is the Table 2 numerator.

use std::collections::HashMap;

use crate::actor::{ActorHandle, Completion, CompletionQueue};
use crate::metrics::{MetricsHub, TrainResult};
use crate::policy::Gradients;
use crate::rollout::{RolloutWorker, WorkerSet};
use crate::util::TimerStat;

pub struct AsyncGradientsOptimizer {
    workers: WorkerSet,

    // Timers, exactly like the original's TimerStat instrumentation.
    wait_timer: TimerStat,
    apply_timer: TimerStat,
    dispatch_timer: TimerStat,

    // Training information.
    num_steps_sampled: usize,
    num_steps_trained: usize,

    // The completion queue + in-flight bookkeeping (ray.wait analog).
    results: CompletionQueue<Gradients>,
    pending_gradients: HashMap<usize, ActorHandle<RolloutWorker>>,
    next_tag: usize,

    hub: MetricsHub,
    started: bool,
}

impl AsyncGradientsOptimizer {
    pub fn new(workers: WorkerSet) -> Self {
        // One task in flight per worker -> the queue bound is the
        // worker count.
        let results =
            CompletionQueue::bounded(workers.num_remotes().max(1));
        AsyncGradientsOptimizer {
            workers,
            wait_timer: TimerStat::new(),
            apply_timer: TimerStat::new(),
            dispatch_timer: TimerStat::new(),
            num_steps_sampled: 0,
            num_steps_trained: 0,
            results,
            pending_gradients: HashMap::new(),
            next_tag: 0,
            hub: MetricsHub::new(100),
            started: false,
        }
    }

    /// Kick off one sample+gradient task on `worker` (the original's
    /// `worker.compute_gradients.remote(worker.sample.remote())`).
    fn launch_gradient_task(&mut self, worker: ActorHandle<RolloutWorker>) {
        let tag = self.next_tag;
        self.next_tag += 1;
        worker.call_into(tag, &self.results, |w| {
            w.sample_and_compute_gradients()
        });
        self.pending_gradients.insert(tag, worker);
    }

    /// Initialization: put weights in the object store and broadcast,
    /// then launch one gradient task per worker.
    fn start(&mut self) {
        // Get weights from the local rollout actor; broadcast one
        // shared Arc (the "object store put" of the original).
        let weights: std::sync::Arc<[f32]> = self
            .workers
            .local
            .call(|w| w.get_weights())
            .expect("learner died")
            .into();
        for worker in self.workers.remotes() {
            // Set weights on the remote rollout actor.
            let w = std::sync::Arc::clone(&weights);
            worker.cast(move |state| state.set_weights(&w));
            // Kick off gradient computation.
            self.launch_gradient_task(worker);
        }
        self.started = true;
    }

    /// One optimization step: wait for a single gradient, apply it on
    /// the local worker, push fresh weights to the producing worker,
    /// relaunch its task.  Mirrors Listing A2's training loop body.
    pub fn step(&mut self) -> TrainResult {
        if !self.started {
            self.start();
        }
        assert!(!self.pending_gradients.is_empty());

        // Wait for one gradient to complete.  This baseline keeps the
        // original's brittleness on purpose (Table 2's comparison
        // point): a worker death is fatal here, where the dataflow
        // version retires the shard and keeps going.
        let (tag, gradient) = self.wait_timer.time(|| {
            match self.results.pop() {
                Completion::Item { tag, value } => (tag, value),
                Completion::Dropped { tag } => {
                    panic!("worker for task {tag} died")
                }
            }
        });
        let worker = self
            .pending_gradients
            .remove(&tag)
            .expect("unknown completion tag");

        // Apply the gradient on the local worker.
        let stats = gradient.stats.clone();
        let count = gradient.count;
        let weights = self.apply_timer.time(|| {
            self.workers
                .local
                .call(move |w| {
                    w.apply_gradients(&gradient);
                    w.get_weights()
                })
                .expect("learner died")
        });
        self.num_steps_sampled += count;
        self.num_steps_trained += count;

        // Set new weights on the worker and launch the next task.
        let dispatch_start = std::time::Instant::now();
        let wt = weights;
        worker.cast(move |w| w.set_weights(&wt));
        self.launch_gradient_task(worker);
        self.dispatch_timer.push(dispatch_start.elapsed());

        // Collect metrics for reporting.
        self.hub.num_env_steps_trained = self.num_steps_trained as u64;
        self.hub.num_grad_updates += 1;
        for (k, v) in stats {
            self.hub.record_learner_stat(&k, v);
        }
        let (episodes, sampled) = self.workers.collect_metrics();
        self.hub.record_episodes(&episodes);
        self.hub.num_env_steps_sampled += sampled as u64;
        self.hub.snapshot()
    }

    pub fn timer_report(&self) -> String {
        format!(
            "wait={:?} apply={:?} dispatch={:?}",
            self.wait_timer.mean(),
            self.apply_timer.mean(),
            self.dispatch_timer.mean()
        )
    }
}
