//! The Spark-Streaming-style executor of Appendix A.1 — PPO implemented
//! the way a stateless microbatch engine forces you to:
//!
//! 1. the driver *saves states to a file* in a watched directory and the
//!    "stream engine" detects the change (loop-back through the
//!    filesystem, A1 lines 11-12, 21-22);
//! 2. transformation functions do not persist variables, so workers and
//!    the trainer are **re-initialized from scratch every iteration**
//!    (fresh actors, fresh PJRT compilation — the analog of restoring a
//!    TF session per task);
//! 3. `map` (parallel sample with restored state) -> `reduce` (concat)
//!    -> `map` (train) -> `foreachRDD` (save states).
//!
//! The per-phase timings this records regenerate Fig. 15's breakdown:
//! the init + I/O overheads are structural to the stateless-dataflow
//! model and do not shrink as workers scale.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::actor::ActorHandle;
use crate::metrics::EpisodeRecord;
use crate::policy::{PgLossKind, PgPolicy, Policy};
use crate::rollout::{CollectMode, RolloutWorker};
use crate::sample_batch::SampleBatch;

/// Per-iteration phase breakdown (Fig. 15's stacked bars).
#[derive(Debug, Clone, Copy, Default)]
pub struct MicrobatchTimings {
    pub init: Duration,
    pub io: Duration,
    pub sample: Duration,
    pub train: Duration,
}

impl MicrobatchTimings {
    pub fn total(&self) -> Duration {
        self.init + self.io + self.sample + self.train
    }
}

pub struct MicrobatchPpo {
    config: crate::algorithms::TrainerConfig,
    epochs: usize,
    workdir: PathBuf,
    iteration: usize,
    pub episodes: Vec<EpisodeRecord>,
    pub num_steps_sampled: usize,
}

impl MicrobatchPpo {
    /// `workdir` is the watched "states" directory (must be writable).
    pub fn new(
        config: crate::algorithms::TrainerConfig,
        epochs: usize,
        workdir: impl Into<PathBuf>,
    ) -> Self {
        let workdir = workdir.into();
        std::fs::create_dir_all(&workdir).expect("create microbatch workdir");
        // Bootstrap: materialize the initial states file.
        let cfg = config.clone();
        let init_weights = std::thread::spawn(move || {
            let p = PgPolicy::create(
                &cfg.artifacts_dir,
                PgLossKind::Ppo { epochs: 1 },
                cfg.lr,
                cfg.seed,
            );
            p.get_weights()
        })
        .join()
        .expect("init policy");
        let me = MicrobatchPpo {
            config,
            epochs,
            workdir,
            iteration: 0,
            episodes: Vec::new(),
            num_steps_sampled: 0,
        };
        me.save_states(0, &init_weights);
        me
    }

    fn states_path(&self, iteration: usize) -> PathBuf {
        self.workdir.join(format!("states_{iteration:06}.bin"))
    }

    fn save_states(&self, iteration: usize, weights: &[f32]) {
        let bytes: Vec<u8> =
            weights.iter().flat_map(|w| w.to_le_bytes()).collect();
        std::fs::write(self.states_path(iteration), bytes)
            .expect("write states");
    }

    /// "Spark detects new states file in path": poll the watch dir until
    /// the expected states file appears.
    fn detect_states(&self, iteration: usize) -> Vec<f32> {
        let path = self.states_path(iteration);
        loop {
            if path.exists() {
                let bytes = std::fs::read(&path).expect("read states");
                return bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    /// One streaming microbatch == one PPO iteration.
    pub fn step(&mut self) -> MicrobatchTimings {
        let mut t = MicrobatchTimings::default();

        // --- I/O: the engine detects + reads the looped-back states ---
        let start = Instant::now();
        let weights = self.detect_states(self.iteration);
        t.io += start.elapsed();

        // --- init: replicate states to *fresh* workers (stateless map
        // tasks re-initialize their variables every iteration) ---
        let start = Instant::now();
        let cfg = self.config.clone();
        let workers: Vec<ActorHandle<RolloutWorker>> = (0..cfg.num_workers)
            .map(|i| {
                let cfg = cfg.clone();
                let w = weights.clone();
                ActorHandle::spawn(&format!("mb_worker_{i}"), move || {
                    let mut policy = PgPolicy::create(
                        &cfg.artifacts_dir,
                        PgLossKind::Ppo { epochs: 1 },
                        cfg.lr,
                        cfg.seed.wrapping_add(i as u64),
                    );
                    policy.set_weights(&w);
                    RolloutWorker::new(
                        cfg.make_envs(i),
                        Box::new(policy),
                        cfg.rollout_fragment_length,
                        CollectMode::OnPolicy,
                    )
                })
            })
            .collect();
        // Barrier on construction (compilation happens in the factory).
        let replies: Vec<_> =
            workers.iter().map(|w| w.call_deferred(|_| ())).collect();
        for r in replies {
            r.recv().expect("worker died");
        }
        t.init += start.elapsed();

        // --- sample: map in parallel, then reduce (concat) ---
        let start = Instant::now();
        let mut collected = Vec::new();
        let mut count = 0usize;
        while count < self.config.train_batch_size {
            let replies: Vec<_> = workers
                .iter()
                .map(|w| w.call_deferred(|state| state.sample()))
                .collect();
            for r in replies {
                let b = r.recv().expect("worker died");
                count += b.len();
                collected.push(b);
            }
        }
        let train_batch = SampleBatch::concat_all(&collected);
        self.num_steps_sampled += train_batch.len();
        for w in &workers {
            self.episodes
                .extend(w.call(|state| state.pop_episodes()).expect("worker died"));
        }
        t.sample += start.elapsed();

        // --- train: restore trainer from states and train ---
        let start = Instant::now();
        let cfg = self.config.clone();
        let epochs = self.epochs;
        let w = weights;
        let new_weights = std::thread::spawn(move || {
            let mut policy = PgPolicy::create(
                &cfg.artifacts_dir,
                PgLossKind::Ppo { epochs },
                cfg.lr,
                cfg.seed,
            );
            policy.set_weights(&w);
            policy.learn_on_batch(&train_batch);
            policy.get_weights()
        })
        .join()
        .expect("trainer task");
        t.train += start.elapsed();

        // --- I/O: save states, triggering the next iteration ---
        let start = Instant::now();
        self.iteration += 1;
        self.save_states(self.iteration, &new_weights);
        t.io += start.elapsed();

        // Workers are dropped here: stateless tasks do not outlive the
        // microbatch.
        t
    }
}
