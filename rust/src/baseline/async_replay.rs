//! Ape-X the low-level way — a direct port of the paper's Listing A4
//! (`AsyncReplayOptimizer`): sample task pool, replay task pool,
//! staleness-tracked weight syncs, priority round-trips, eight timers.
//! Compare with `algorithms::apex_plan` — this file is what the flow
//! version collapses into three subflows + one Concurrently.

use std::collections::HashMap;

use crate::actor::{Completion, CompletionQueue};
use crate::metrics::{MetricsHub, TrainResult};
use crate::ops::{create_replay_actors, ReplayActor};
use crate::replay::ReplaySample;
use crate::rollout::WorkerSet;
use crate::sample_batch::SampleBatch;
use crate::util::{Rng, TimerStat};

const SAMPLE_QUEUE_DEPTH: usize = 2;
const REPLAY_QUEUE_DEPTH: usize = 4;

pub struct AsyncReplayOptimizer {
    workers: WorkerSet,
    replay_actors: Vec<ReplayActor>,
    max_weight_sync_delay: usize,
    target_update_every: usize,

    // Timers, mirroring Listing A4's dict of TimerStats.
    timers: HashMap<&'static str, TimerStat>,

    // Sample task pool: completion queue + tag -> worker map.
    samples: CompletionQueue<SampleBatch>,
    sample_tags: HashMap<usize, usize>, // tag -> worker index

    // Replay task pool.
    replays: CompletionQueue<Option<ReplaySample>>,
    replay_tags: HashMap<usize, usize>, // tag -> replay actor index

    next_tag: usize,
    steps_since_update: HashMap<usize, usize>,
    steps_since_target: usize,
    num_weight_syncs: usize,
    num_steps_sampled: usize,
    num_steps_trained: usize,
    rng: Rng,
    hub: MetricsHub,
    started: bool,
}

impl AsyncReplayOptimizer {
    pub fn new(
        workers: WorkerSet,
        num_replay_actors: usize,
        buffer_capacity: usize,
        learning_starts: usize,
        replay_batch_size: usize,
        max_weight_sync_delay: usize,
        target_update_every: usize,
    ) -> Self {
        let obs_dim =
            workers.local.call(|w| w.obs_dim()).expect("learner died");
        let replay_actors = create_replay_actors(
            num_replay_actors,
            obs_dim,
            buffer_capacity,
            learning_starts,
            replay_batch_size,
        );
        let samples = CompletionQueue::bounded(
            (workers.num_remotes() * SAMPLE_QUEUE_DEPTH).max(1),
        );
        let replays = CompletionQueue::bounded(
            (replay_actors.len() * REPLAY_QUEUE_DEPTH).max(1),
        );
        let timers = [
            "put_weights",
            "get_samples",
            "sample_processing",
            "replay_processing",
            "update_priorities",
            "train",
        ]
        .into_iter()
        .map(|k| (k, TimerStat::new()))
        .collect();
        AsyncReplayOptimizer {
            workers,
            replay_actors,
            max_weight_sync_delay,
            target_update_every,
            timers,
            samples,
            sample_tags: HashMap::new(),
            replays,
            replay_tags: HashMap::new(),
            next_tag: 0,
            steps_since_update: HashMap::new(),
            steps_since_target: 0,
            num_weight_syncs: 0,
            num_steps_sampled: 0,
            num_steps_trained: 0,
            rng: Rng::new(0xA9E),
            hub: MetricsHub::new(100),
            started: false,
        }
    }

    fn launch_sample_task(&mut self, worker_idx: usize) {
        // A slot tombstoned by a scale-down has nothing to relaunch —
        // skipping it must not crash the optimizer.
        let Some(worker) = self.workers.remote(worker_idx) else {
            return;
        };
        let tag = self.next_tag;
        self.next_tag += 1;
        worker.call_into(tag, &self.samples, |w| w.sample());
        self.sample_tags.insert(tag, worker_idx);
    }

    fn launch_replay_task(&mut self, actor_idx: usize) {
        let tag = self.next_tag;
        self.next_tag += 1;
        self.replay_actors[actor_idx].call_into(
            tag,
            &self.replays,
            |ra| ra.replay(),
        );
        self.replay_tags.insert(tag, actor_idx);
    }

    fn start(&mut self) {
        // Kick off replay tasks for local gradient updates.
        for actor_idx in 0..self.replay_actors.len() {
            for _ in 0..REPLAY_QUEUE_DEPTH {
                self.launch_replay_task(actor_idx);
            }
        }
        // Kick off async background sampling with fresh weights (one
        // shared Arc across all workers).
        let weights: std::sync::Arc<[f32]> = self
            .workers
            .local
            .call(|w| w.get_weights())
            .expect("learner died")
            .into();
        for worker_idx in 0..self.workers.num_remotes() {
            let Some(worker) = self.workers.remote(worker_idx) else {
                continue; // tombstoned slot
            };
            let w = std::sync::Arc::clone(&weights);
            worker.cast(move |state| state.set_weights(&w));
            self.steps_since_update.insert(worker_idx, 0);
            for _ in 0..SAMPLE_QUEUE_DEPTH {
                self.launch_sample_task(worker_idx);
            }
        }
        self.started = true;
    }

    /// One optimization step (Listing A4's `step`): drain completed
    /// sample tasks into replay actors, drain completed replay tasks
    /// into the learner, update priorities, manage weight staleness.
    pub fn step(&mut self) -> TrainResult {
        if !self.started {
            self.start();
        }

        // --- Sample processing ---
        let mut sample_timer = self.timers.remove("sample_processing").unwrap();
        sample_timer.time(|| {
            // Drain all completed sample tasks without blocking.
            while let Some(done) = self.samples.try_pop() {
                let (tag, batch) = match done {
                    Completion::Item { tag, value } => (tag, value),
                    Completion::Dropped { tag } => {
                        panic!("sample worker for task {tag} died")
                    }
                };
                let worker_idx =
                    self.sample_tags.remove(&tag).expect("unknown tag");
                let count = batch.len();
                self.num_steps_sampled += count;

                // Randomly choose one replay actor and send the data.
                let ra =
                    &self.replay_actors[self.rng.below(self.replay_actors.len())];
                ra.cast(move |state| state.add_batch(&batch));

                // Weight staleness accounting; sync when overdue.
                let since =
                    self.steps_since_update.entry(worker_idx).or_insert(0);
                *since += count;
                if *since >= self.max_weight_sync_delay {
                    *since = 0;
                    let mut put_timer =
                        self.timers.remove("put_weights").unwrap();
                    let weights = put_timer.time(|| {
                        self.workers
                            .local
                            .call(|w| w.get_weights())
                            .expect("learner died")
                    });
                    self.timers.insert("put_weights", put_timer);
                    if let Some(worker) = self.workers.remote(worker_idx) {
                        worker.cast(move |w| w.set_weights(&weights));
                    }
                    self.num_weight_syncs += 1;
                }
                // Kick off another sample request.
                self.launch_sample_task(worker_idx);
            }
        });
        self.timers.insert("sample_processing", sample_timer);

        // --- Replay processing: block for at least one replay result ---
        let mut replay_timer = self.timers.remove("replay_processing").unwrap();
        let mut learned = Vec::new();
        replay_timer.time(|| {
            let mut process = |this: &mut Self,
                               tag: usize,
                               maybe: Option<ReplaySample>| {
                let actor_idx = this.replay_tags.remove(&tag).unwrap();
                this.launch_replay_task(actor_idx);
                if let Some(sample) = maybe {
                    learned.push((actor_idx, sample));
                }
            };
            let unpack = |c: Completion<Option<ReplaySample>>| match c {
                Completion::Item { tag, value } => (tag, value),
                Completion::Dropped { tag } => {
                    panic!("replay actor for task {tag} died")
                }
            };
            // Block for one...
            let replays = self.replays.clone();
            let (tag, maybe) = unpack(replays.pop());
            process(self, tag, maybe);
            // ...then drain whatever else is ready.
            while let Some(c) = replays.try_pop() {
                let (tag, maybe) = unpack(c);
                process(self, tag, maybe);
            }
        });
        self.timers.insert("replay_processing", replay_timer);

        // --- Train + update priorities ---
        for (actor_idx, sample) in learned {
            let steps = sample.batch.len();
            let indices = sample.indices;
            let batch = sample.batch;
            let mut train_timer = self.timers.remove("train").unwrap();
            let (stats, td) = train_timer.time(|| {
                self.workers
                    .local
                    .call(move |w| w.learn_and_td(&batch))
                    .expect("learner died")
            });
            train_timer.push_units_processed(steps as f64);
            self.timers.insert("train", train_timer);

            let mut prio_timer =
                self.timers.remove("update_priorities").unwrap();
            prio_timer.time(|| {
                self.replay_actors[actor_idx]
                    .cast(move |ra| ra.update_priorities(&indices, &td));
            });
            self.timers.insert("update_priorities", prio_timer);

            self.num_steps_trained += steps;
            self.steps_since_target += steps;
            for (k, v) in stats {
                self.hub.record_learner_stat(&k, v);
            }
            self.hub.num_grad_updates += 1;
            if self.steps_since_target >= self.target_update_every {
                self.steps_since_target = 0;
                self.workers.local.cast(|w| w.policy.update_target());
            }
        }

        self.hub.num_env_steps_trained = self.num_steps_trained as u64;
        let (episodes, sampled) = self.workers.collect_metrics();
        self.hub.record_episodes(&episodes);
        self.hub.num_env_steps_sampled += sampled as u64;
        self.hub.snapshot()
    }

    pub fn timer_report(&self) -> String {
        let mut parts: Vec<String> = self
            .timers
            .iter()
            .map(|(k, t)| format!("{k}={:?}", t.mean()))
            .collect();
        parts.sort();
        format!("{} weight_syncs={}", parts.join(" "), self.num_weight_syncs)
    }
}
