//! Low-level baseline implementations — the "original RLlib" comparison
//! points of the paper's evaluation.
//!
//! Each optimizer here re-implements one algorithm's distributed
//! execution directly against actor/RPC primitives, in the style of the
//! paper's Listing A2 (A3C) and Listing A4 (Ape-X): explicit pending-
//! task maps, completion queues, per-phase timers, manual weight
//! bookkeeping.  The *numerics are identical* to the dataflow plans in
//! `crate::algorithms` (same workers, same policies, same artifacts) —
//! only the coordination code differs, which is exactly what Table 2
//! and Fig. 13 compare.
//!
//! `microbatch` is the Spark-Streaming-style executor of Appendix A.1:
//! stateless per-iteration tasks, full state serialization through the
//! filesystem, re-initialization every iteration.

mod async_gradients;
mod async_pipeline;
mod async_replay;
mod microbatch;
mod sync_replay;
mod sync_samples;

pub use async_gradients::AsyncGradientsOptimizer;
pub use async_pipeline::AsyncPipelineOptimizer;
pub use async_replay::AsyncReplayOptimizer;
pub use microbatch::{MicrobatchPpo, MicrobatchTimings};
pub use sync_replay::SyncReplayOptimizer;
pub use sync_samples::SyncSamplesOptimizer;
