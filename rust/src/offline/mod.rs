//! Offline RL dataflow — durable experience as just another edge.
//!
//! The source paper's argument is that RL workloads decompose into
//! dataflow operators over experience streams; RLlib's other pitch is
//! training "purely from offline (historic) datasets".  This module
//! supplies the durable half of that story:
//!
//! * [`EpisodeLogWriter`] — a sink that appends [`SampleBatch`]
//!   fragments to an on-disk stream of segment files as
//!   length-prefixed, CRC-framed binary records
//!   (`crate::sample_batch::wire` frames), rotating segments at a size
//!   threshold.  `RolloutWorker::set_log_sink` and the episode
//!   gateway's pump tap it so live traffic can be persisted without
//!   touching the hot loop's allocation behavior.
//! * [`LogStreamReader`] — an incremental tail-follower over those
//!   segments: bounded parser state (one segment position + one frame
//!   scratch buffer), tolerant of a truncated in-progress tail frame
//!   (waits, never double-reads), skips corrupt-CRC frames (counted),
//!   and resumes across segment rotation.  `ops::read_from_logs` lifts
//!   it into a dataflow source feeding the sharded replay service
//!   exactly like `store_to_replay_buffer` feeds it from live rollouts.
//! * [`OfflineCounters`] / [`OfflineLogStats`] — shared telemetry
//!   (frames, transitions, bytes, corruption, reader lag) surfaced on
//!   `TrainResult::offline` through the `ops::Reporting` builder.
//!
//! On top of these, `algorithms::offline_dqn_plan` trains with **zero
//! envs constructed** (reader → replay → learner) and
//! `ops::ope_estimate` scores a target policy against the logged
//! behavior policy by importance sampling.  `docs/offline.md` documents
//! the frame format and the reader's resume protocol.

mod reader;
mod writer;

pub use reader::{discover_streams, LogStreamReader};
pub use writer::{EpisodeLogWriter, WriterConfig};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// File extension of log segments (`{stream}.{seq:06}.flog`).
pub const SEGMENT_EXT: &str = "flog";

/// Shared offline-path telemetry.  The reader(s) bump these; the
/// metrics op snapshots them per report.  One `Arc` is shared across
/// every reader of a plan so multi-stream ingestion aggregates.
#[derive(Debug, Default)]
pub struct OfflineCounters {
    /// Frames decoded and emitted downstream.
    pub frames: AtomicU64,
    /// Transitions (batch rows) across emitted frames.
    pub transitions: AtomicU64,
    /// Bytes consumed as complete frames (header + payload).
    pub bytes: AtomicU64,
    /// Frames dropped for CRC mismatch or undecodable payload.
    pub corrupt: AtomicU64,
    /// Torn tails abandoned at segment rotation (a writer died
    /// mid-frame; the partial frame is unrecoverable by design).
    pub truncated: AtomicU64,
    /// Idle polls (no complete frame available anywhere).
    pub waits: AtomicU64,
    /// Gauge: bytes on disk not yet consumed (reader lag), summed over
    /// readers sharing these counters.
    pub lag_bytes: AtomicU64,
    /// Gauge: streams being followed.
    pub streams: AtomicU64,
}

impl OfflineCounters {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Point-in-time snapshot (rates are filled in by the metrics op,
    /// which owns the report clock).
    pub fn snapshot(&self) -> OfflineLogStats {
        OfflineLogStats {
            streams: self.streams.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            transitions: self.transitions.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            corrupt_frames: self.corrupt.load(Ordering::Relaxed),
            truncated_tails: self.truncated.load(Ordering::Relaxed),
            lag_bytes: self.lag_bytes.load(Ordering::Relaxed),
            frames_per_s: 0.0,
        }
    }
}

/// Offline-ingestion section of `TrainResult` (mirrors
/// `replay::ReplayBacklogStats`: a plain snapshot struct the metrics
/// layer can embed without holding the live counters).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OfflineLogStats {
    /// Streams being followed.
    pub streams: u64,
    /// Cumulative frames decoded.
    pub frames: u64,
    /// Cumulative transitions ingested.
    pub transitions: u64,
    /// Cumulative frame bytes consumed.
    pub bytes: u64,
    /// Frames dropped on CRC/decode failure.
    pub corrupt_frames: u64,
    /// Torn tail frames abandoned at rotation.
    pub truncated_tails: u64,
    /// Reader lag gauge: on-disk bytes not yet consumed.
    pub lag_bytes: u64,
    /// Decode rate over the last report interval (filled by the
    /// reporting op from consecutive snapshots).
    pub frames_per_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_snapshot_reads_all_fields() {
        let c = OfflineCounters::new();
        c.frames.store(3, Ordering::Relaxed);
        c.transitions.store(96, Ordering::Relaxed);
        c.bytes.store(4096, Ordering::Relaxed);
        c.corrupt.store(1, Ordering::Relaxed);
        c.truncated.store(2, Ordering::Relaxed);
        c.lag_bytes.store(7, Ordering::Relaxed);
        c.streams.store(4, Ordering::Relaxed);
        let s = c.snapshot();
        assert_eq!(
            s,
            OfflineLogStats {
                streams: 4,
                frames: 3,
                transitions: 96,
                bytes: 4096,
                corrupt_frames: 1,
                truncated_tails: 2,
                lag_bytes: 7,
                frames_per_s: 0.0,
            }
        );
    }
}
