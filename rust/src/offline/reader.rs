//! The streaming log source: an incremental tail-follower over segment
//! files.
//!
//! The reader is a *parser/source split* with bounded state: one
//! `(segment seq, byte offset)` cursor plus one reused frame buffer —
//! no accumulation proportional to log size.  Each [`LogStreamReader::poll`]
//! makes at most one frame of progress and never blocks, so the ops
//! layer can drive many readers round-robin inside a dataflow source.
//!
//! Resume protocol (see `docs/offline.md`):
//!
//! * **Complete frame at cursor** → decode, advance, emit.  CRC or
//!   payload-decode failure → count `corrupt`, skip exactly that frame
//!   (the length prefix preserves framing), continue.
//! * **Partial frame at cursor, no later segment** → a writer may still
//!   be appending: wait (`None`), cursor unchanged — when the flush
//!   completes the same bytes are re-examined, so nothing is ever
//!   double-read or lost.
//! * **Partial frame at cursor, later segment exists** → the writer
//!   died mid-write and a restarted writer rotated: count `truncated`,
//!   abandon the torn tail, resume at the next segment.
//! * **Implausible length word** → framing is lost; count `corrupt`
//!   once and fast-forward to the next rotation boundary (the only
//!   place framing is re-established).

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::writer::{parse_segment_name, segment_path};
use super::OfflineCounters;
use crate::sample_batch::wire;
use crate::SampleBatch;

/// Tail-follows one stream's segments, emitting decoded batches.
#[derive(Debug)]
pub struct LogStreamReader {
    dir: PathBuf,
    stream: String,
    counters: Arc<OfflineCounters>,
    /// Segment currently being consumed.
    seq: u64,
    /// Bytes of that segment already consumed (frame-aligned, except
    /// after a lost-framing event).
    offset: u64,
    file: Option<File>,
    /// Framing lost in the current segment — skip to the next rotation
    /// boundary.
    skip_to_next_segment: bool,
    /// Reused header+payload scratch.
    buf: Vec<u8>,
    /// Last lag value this reader contributed to the shared gauge.
    last_lag: u64,
}

/// Stream names present in `dir`, sorted and deduplicated.
pub fn discover_streams(dir: impl AsRef<Path>) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir.as_ref()) else {
        return names;
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some((stream, _)) = parse_segment_name(name) {
            if !names.iter().any(|n| n == stream) {
                names.push(stream.to_string());
            }
        }
    }
    names.sort();
    names
}

impl LogStreamReader {
    /// Follow `stream` under `dir` from its oldest existing segment
    /// (or segment 0 if none exist yet — the reader may be started
    /// before the writer).
    pub fn follow(
        dir: impl Into<PathBuf>,
        stream: impl Into<String>,
        counters: Arc<OfflineCounters>,
    ) -> Self {
        let dir = dir.into();
        let stream = stream.into();
        let seq = oldest_seq(&dir, &stream).unwrap_or(0);
        counters.streams.fetch_add(1, Ordering::Relaxed);
        LogStreamReader {
            dir,
            stream,
            counters,
            seq,
            offset: 0,
            file: None,
            skip_to_next_segment: false,
            buf: Vec::new(),
            last_lag: 0,
        }
    }

    /// Stream being followed.
    pub fn stream(&self) -> &str {
        &self.stream
    }

    /// `(segment seq, byte offset)` cursor — bounded parser state.
    pub fn position(&self) -> (u64, u64) {
        (self.seq, self.offset)
    }

    /// Try to make one frame of progress.  `None` means "no complete
    /// frame available right now" — either fully caught up with a live
    /// writer or waiting out a torn tail.  Never blocks, never panics
    /// on torn/corrupt input.
    pub fn poll(&mut self) -> Option<SampleBatch> {
        loop {
            // Ensure the current segment is open.
            if self.file.is_none() {
                match File::open(segment_path(&self.dir, &self.stream, self.seq)) {
                    Ok(f) => self.file = Some(f),
                    Err(_) => {
                        // Current segment absent (never created, or
                        // deleted): hop to the next existing one, else
                        // idle.  `next != seq` guards the transient
                        // case where the file appeared mid-scan.
                        match self.next_seq_at_or_after(self.seq) {
                            Some(next) if next != self.seq => {
                                self.seq = next;
                                self.offset = 0;
                                self.skip_to_next_segment = false;
                                continue;
                            }
                            _ => return self.idle(),
                        }
                    }
                }
            }

            let file_len = match self.file.as_ref().unwrap().metadata() {
                Ok(m) => m.len(),
                Err(_) => return self.idle(),
            };
            let avail = file_len.saturating_sub(self.offset);

            if self.skip_to_next_segment {
                // Framing lost here; only a rotation boundary recovers.
                if self.advance_if_rotated() {
                    continue;
                }
                return self.idle();
            }

            if avail == 0 {
                if self.advance_to_next_segment() {
                    continue;
                }
                return self.idle();
            }

            if avail < wire::FRAME_HEADER_BYTES as u64 {
                return self.torn_tail_or_wait();
            }

            // Read the header, bound-check the length word.
            if self.read_at(self.offset, wire::FRAME_HEADER_BYTES).is_err() {
                return self.idle();
            }
            let len = u32::from_le_bytes([
                self.buf[0], self.buf[1], self.buf[2], self.buf[3],
            ]);
            if len > wire::MAX_FRAME_BYTES {
                self.counters.corrupt.fetch_add(1, Ordering::Relaxed);
                self.skip_to_next_segment = true;
                continue;
            }
            let frame_len = wire::FRAME_HEADER_BYTES as u64 + len as u64;
            if avail < frame_len {
                return self.torn_tail_or_wait();
            }

            // A complete frame is on disk: read and decode it.
            if self.read_at(self.offset, frame_len as usize).is_err() {
                return self.idle();
            }
            let status = wire::try_decode_frame(&self.buf);
            match status {
                wire::FrameStatus::Ok { payload_start, payload_end, consumed } => {
                    match wire::decode_batch(&self.buf[payload_start..payload_end])
                    {
                        Ok(batch) => {
                            self.offset += consumed as u64;
                            self.counters.frames.fetch_add(1, Ordering::Relaxed);
                            self.counters
                                .transitions
                                .fetch_add(batch.len() as u64, Ordering::Relaxed);
                            self.counters
                                .bytes
                                .fetch_add(consumed as u64, Ordering::Relaxed);
                            self.set_lag(self.last_lag.saturating_sub(
                                consumed as u64,
                            ));
                            return Some(batch);
                        }
                        Err(_) => {
                            // CRC matched but the payload is not a
                            // batch — skip the frame, framing intact.
                            self.offset += consumed as u64;
                            self.counters.corrupt.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                }
                wire::FrameStatus::BadCrc { consumed } => {
                    self.offset += consumed as u64;
                    self.counters.corrupt.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                wire::FrameStatus::BadLength => {
                    self.counters.corrupt.fetch_add(1, Ordering::Relaxed);
                    self.skip_to_next_segment = true;
                    continue;
                }
                wire::FrameStatus::Incomplete => {
                    // Shrunk between metadata and read — treat as tail.
                    return self.torn_tail_or_wait();
                }
            }
        }
    }

    /// Partial frame at the cursor: torn (later segment exists —
    /// writer restarted past it) or in-flight (wait).
    fn torn_tail_or_wait(&mut self) -> Option<SampleBatch> {
        if self.next_seq_after(self.seq).is_some() {
            self.counters.truncated.fetch_add(1, Ordering::Relaxed);
            self.skip_to_next_segment = false;
            let _ = self.advance_to_next_segment();
            // Tail-call back into poll via the caller: returning None
            // here would under-report an *available* next segment, so
            // recurse once — bounded by segment count, and segments
            // with torn tails are consumed permanently.
            return self.poll();
        }
        self.idle()
    }

    /// Move to the next existing segment, if any.
    fn advance_to_next_segment(&mut self) -> bool {
        match self.next_seq_after(self.seq) {
            Some(next) => {
                self.seq = next;
                self.offset = 0;
                self.file = None;
                self.skip_to_next_segment = false;
                true
            }
            None => false,
        }
    }

    fn advance_if_rotated(&mut self) -> bool {
        self.advance_to_next_segment()
    }

    /// Smallest existing segment seq strictly greater than `after`.
    fn next_seq_after(&self, after: u64) -> Option<u64> {
        self.scan_min_seq(|seq| seq > after)
    }

    /// Smallest existing segment seq `>= at`.
    fn next_seq_at_or_after(&self, at: u64) -> Option<u64> {
        self.scan_min_seq(|seq| seq >= at)
    }

    fn scan_min_seq(&self, keep: impl Fn(u64) -> bool) -> Option<u64> {
        let mut best: Option<u64> = None;
        let entries = std::fs::read_dir(&self.dir).ok()?;
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some((stream, seq)) = parse_segment_name(name) {
                if stream == self.stream
                    && keep(seq)
                    && best.map_or(true, |b| seq < b)
                {
                    best = Some(seq);
                }
            }
        }
        best
    }

    /// Idle bookkeeping: refresh the lag gauge (the dir scan the idle
    /// path pays anyway), count the wait, yield nothing.
    fn idle(&mut self) -> Option<SampleBatch> {
        self.counters.waits.fetch_add(1, Ordering::Relaxed);
        let lag = self.compute_lag();
        self.set_lag(lag);
        None
    }

    /// Unconsumed bytes: remainder of the current segment plus all
    /// later segments.
    fn compute_lag(&self) -> u64 {
        let mut lag = 0u64;
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return 0 };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some((stream, seq)) = parse_segment_name(name) else { continue };
            if stream != self.stream {
                continue;
            }
            let Ok(meta) = entry.metadata() else { continue };
            if seq > self.seq {
                lag += meta.len();
            } else if seq == self.seq {
                lag += meta.len().saturating_sub(self.offset);
            }
        }
        lag
    }

    /// Publish this reader's lag into the shared gauge as a delta, so
    /// multiple readers sharing one `OfflineCounters` aggregate.
    fn set_lag(&mut self, lag: u64) {
        if lag >= self.last_lag {
            self.counters
                .lag_bytes
                .fetch_add(lag - self.last_lag, Ordering::Relaxed);
        } else {
            self.counters
                .lag_bytes
                .fetch_sub(self.last_lag - lag, Ordering::Relaxed);
        }
        self.last_lag = lag;
    }

    /// Read `n` bytes at `pos` into the scratch buffer.
    fn read_at(&mut self, pos: u64, n: usize) -> std::io::Result<()> {
        self.buf.resize(n, 0);
        let f = self.file.as_mut().expect("segment open");
        f.seek(SeekFrom::Start(pos))?;
        f.read_exact(&mut self.buf)
    }
}

impl Drop for LogStreamReader {
    fn drop(&mut self) {
        self.set_lag(0);
        self.counters.streams.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Smallest existing segment seq of `stream`, if any.
fn oldest_seq(dir: &Path, stream: &str) -> Option<u64> {
    let mut best: Option<u64> = None;
    let entries = std::fs::read_dir(dir).ok()?;
    for entry in entries.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some((s, seq)) = parse_segment_name(name) {
            if s == stream && best.map_or(true, |b| seq < b) {
                best = Some(seq);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::super::writer::{EpisodeLogWriter, WriterConfig};
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("flowrl_logr_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn batch(tag: f32, n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_transition_with_logp(
                &[tag, i as f32],
                i as i32 % 2,
                tag,
                &[tag, i as f32 + 1.0],
                i + 1 == n,
                -0.5,
            );
        }
        b.build()
    }

    #[test]
    fn tail_follow_reads_frames_in_order() {
        let dir = tmp_dir("tail");
        let counters = OfflineCounters::new();
        let mut r = LogStreamReader::follow(&dir, "s", counters.clone());
        // Reader started before the writer: polls are quiet waits.
        assert!(r.poll().is_none());
        let mut w =
            EpisodeLogWriter::create(&dir, "s", WriterConfig::default()).unwrap();
        for tag in 0..5 {
            w.append(&batch(tag as f32, 3)).unwrap();
        }
        for tag in 0..5 {
            let got = r.poll().expect("frame available");
            assert_eq!(got.rewards[0], tag as f32);
            assert_eq!(got.len(), 3);
        }
        assert!(r.poll().is_none()); // caught up
        // Interleaved append/poll: the reader resumes at the tail.
        w.append(&batch(9.0, 2)).unwrap();
        assert_eq!(r.poll().unwrap().rewards[0], 9.0);
        let s = counters.snapshot();
        assert_eq!(s.frames, 6);
        assert_eq!(s.transitions, 17);
        assert_eq!(s.corrupt_frames, 0);
        assert_eq!(s.truncated_tails, 0);
        assert_eq!(s.lag_bytes, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resumes_across_rotation() {
        let dir = tmp_dir("rotation");
        let counters = OfflineCounters::new();
        let mut w = EpisodeLogWriter::create(
            &dir,
            "s",
            WriterConfig { segment_bytes: 200 },
        )
        .unwrap();
        for tag in 0..20 {
            w.append(&batch(tag as f32, 2)).unwrap();
        }
        assert!(w.current_seq() >= 2, "test needs multiple segments");
        let mut r = LogStreamReader::follow(&dir, "s", counters.clone());
        for tag in 0..20 {
            assert_eq!(r.poll().expect("frame").rewards[0], tag as f32);
        }
        assert!(r.poll().is_none());
        assert_eq!(counters.snapshot().frames, 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn discover_streams_lists_unique_sorted() {
        let dir = tmp_dir("discover");
        let _ = EpisodeLogWriter::create(&dir, "b", WriterConfig::default());
        let _ = EpisodeLogWriter::create(&dir, "a", WriterConfig::default());
        let _ = EpisodeLogWriter::create(&dir, "a", WriterConfig::default());
        std::fs::write(dir.join("notalog.txt"), b"x").unwrap();
        assert_eq!(discover_streams(&dir), vec!["a".to_string(), "b".to_string()]);
        assert!(discover_streams(dir.join("missing")).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lag_gauge_tracks_unread_bytes() {
        let dir = tmp_dir("lag");
        let counters = OfflineCounters::new();
        let mut w =
            EpisodeLogWriter::create(&dir, "s", WriterConfig::default()).unwrap();
        w.append(&batch(0.0, 4)).unwrap();
        w.append(&batch(1.0, 4)).unwrap();
        let (_, bytes_written, _) = w.counters();
        let mut r = LogStreamReader::follow(&dir, "s", counters.clone());
        // Consume one frame then go idle: lag = remaining frame.
        let first = r.poll().unwrap();
        assert_eq!(first.rewards[0], 0.0);
        let _ = r.poll(); // second frame
        assert!(r.poll().is_none()); // idle → lag recomputed
        assert_eq!(counters.snapshot().lag_bytes, 0);
        // New unread frame shows up as lag after an idle poll.
        w.append(&batch(2.0, 4)).unwrap();
        drop(r);
        let mut r2 = LogStreamReader::follow(&dir, "s", counters.clone());
        assert!(r2.poll().is_some()); // frame 0 again (fresh reader)
        let _ = (bytes_written, &mut r2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streams_gauge_counts_live_readers() {
        let dir = tmp_dir("gauge");
        let counters = OfflineCounters::new();
        let r1 = LogStreamReader::follow(&dir, "a", counters.clone());
        let r2 = LogStreamReader::follow(&dir, "b", counters.clone());
        assert_eq!(counters.snapshot().streams, 2);
        drop(r1);
        assert_eq!(counters.snapshot().streams, 1);
        drop(r2);
        assert_eq!(counters.snapshot().streams, 0);
    }
}
