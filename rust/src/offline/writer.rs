//! The episode-log sink: durable `SampleBatch` frames with segment
//! rotation.
//!
//! Layout on disk: a *stream* is a directory of segment files named
//! `{stream}.{seq:06}.flog`, each a concatenation of wire frames
//! (`u32 len | u32 crc | payload`, see [`crate::sample_batch::wire`]).
//! The writer appends to the highest-seq segment it created and rotates
//! to `seq + 1` before any append that would push the current segment
//! past `segment_bytes`.  A re-created writer (crash restart) never
//! appends to an existing segment — the old tail might be torn — it
//! starts a fresh one, which is exactly the rotation event the reader
//! already knows how to resume across.

use std::fs::{File, OpenOptions};
use std::io::{self, BufWriter, Write};
use std::path::{Path, PathBuf};

use super::SEGMENT_EXT;
use crate::sample_batch::wire;
use crate::SampleBatch;

/// Default rotation threshold — small enough that a training run
/// produces several segments (rotation is the recovery boundary), large
/// enough that the directory stays short.
pub const DEFAULT_SEGMENT_BYTES: u64 = 8 * 1024 * 1024;

#[derive(Debug, Clone, Copy)]
pub struct WriterConfig {
    /// Rotate to a new segment before an append would push the current
    /// one past this many bytes.
    pub segment_bytes: u64,
}

impl Default for WriterConfig {
    fn default() -> Self {
        WriterConfig { segment_bytes: DEFAULT_SEGMENT_BYTES }
    }
}

/// Appends CRC-framed `SampleBatch` records to a rotating segment
/// stream.  One writer owns one stream; it is `Send` (a rollout worker
/// or gateway shard carries its own).
#[derive(Debug)]
pub struct EpisodeLogWriter {
    dir: PathBuf,
    stream: String,
    config: WriterConfig,
    seq: u64,
    file: BufWriter<File>,
    segment_len: u64,
    payload_scratch: Vec<u8>,
    frame_scratch: Vec<u8>,
    frames: u64,
    bytes: u64,
    write_errors: u64,
}

/// `{stream}.{seq:06}.flog` under `dir`.
pub(super) fn segment_path(dir: &Path, stream: &str, seq: u64) -> PathBuf {
    dir.join(format!("{stream}.{seq:06}.{SEGMENT_EXT}"))
}

/// Parse `(stream, seq)` out of a segment file name; `None` for
/// non-segment files.
pub(super) fn parse_segment_name(name: &str) -> Option<(&str, u64)> {
    let rest = name.strip_suffix(&format!(".{SEGMENT_EXT}"))?;
    let (stream, seq) = rest.rsplit_once('.')?;
    if stream.is_empty() {
        return None;
    }
    Some((stream, seq.parse().ok()?))
}

/// Highest existing segment seq of `stream` in `dir`, if any.
fn max_existing_seq(dir: &Path, stream: &str) -> io::Result<Option<u64>> {
    let mut max = None;
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some((s, seq)) = parse_segment_name(name) {
            if s == stream && max.map_or(true, |m| seq > m) {
                max = Some(seq);
            }
        }
    }
    Ok(max)
}

impl EpisodeLogWriter {
    /// Open a stream for appending.  Creates `dir` if needed and starts
    /// a new segment *after* any existing ones (crash-restart safe: a
    /// possibly-torn old tail is left for the reader to skip at
    /// rotation, never appended to).
    pub fn create(
        dir: impl Into<PathBuf>,
        stream: impl Into<String>,
        config: WriterConfig,
    ) -> io::Result<Self> {
        let dir = dir.into();
        let stream = stream.into();
        assert!(
            !stream.contains('.') && !stream.contains('/'),
            "stream name {stream:?} must not contain '.' or '/'"
        );
        std::fs::create_dir_all(&dir)?;
        let seq = max_existing_seq(&dir, &stream)?.map_or(0, |m| m + 1);
        let file = open_segment(&dir, &stream, seq)?;
        Ok(EpisodeLogWriter {
            dir,
            stream,
            config,
            seq,
            file,
            segment_len: 0,
            payload_scratch: Vec::new(),
            frame_scratch: Vec::new(),
            frames: 0,
            bytes: 0,
            write_errors: 0,
        })
    }

    /// Append one fragment as a single frame, rotating first if the
    /// current segment is non-empty and would overflow.  The frame is
    /// assembled in reused scratch buffers and written+flushed as one
    /// contiguous slice, so a crash tears at most the *tail* frame —
    /// everything flushed before it is intact.
    // flowlint: hot-path (steady-state append reuses scratch; pinned by tests/offline_alloc.rs; rotate() is the cold path)
    pub fn append(&mut self, batch: &SampleBatch) -> io::Result<()> {
        self.payload_scratch.clear();
        wire::encode_batch(batch, &mut self.payload_scratch);
        self.frame_scratch.clear();
        wire::encode_frame(&self.payload_scratch, &mut self.frame_scratch);
        let frame_len = self.frame_scratch.len() as u64;
        if self.segment_len > 0
            && self.segment_len + frame_len > self.config.segment_bytes
        {
            self.rotate()?;
        }
        let res = self
            .file
            .write_all(&self.frame_scratch)
            .and_then(|()| self.file.flush());
        if let Err(e) = res {
            self.write_errors += 1;
            return Err(e);
        }
        self.segment_len += frame_len;
        self.frames += 1;
        self.bytes += frame_len;
        Ok(())
    }

    fn rotate(&mut self) -> io::Result<()> {
        self.file.flush()?;
        self.seq += 1;
        self.file = open_segment(&self.dir, &self.stream, self.seq)?;
        self.segment_len = 0;
        Ok(())
    }

    /// Stream name this writer appends to.
    pub fn stream(&self) -> &str {
        &self.stream
    }

    /// Directory holding the segments.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Seq of the segment currently being appended to.
    pub fn current_seq(&self) -> u64 {
        self.seq
    }

    /// (frames appended, frame bytes written, failed appends).
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.frames, self.bytes, self.write_errors)
    }
}

fn open_segment(dir: &Path, stream: &str, seq: u64) -> io::Result<BufWriter<File>> {
    let path = segment_path(dir, stream, seq);
    // create_new: a seq collision means two writers own one stream —
    // refuse instead of interleaving frames.
    let file = OpenOptions::new().write(true).create_new(true).open(&path)?;
    Ok(BufWriter::new(file))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("flowrl_logw_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn batch(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_transition_with_logp(
                &[i as f32, 0.5],
                1,
                1.0,
                &[i as f32 + 1.0, 0.5],
                false,
                -0.69,
            );
        }
        b.build()
    }

    #[test]
    fn parse_segment_name_roundtrip() {
        let p = segment_path(Path::new("/x"), "rollout", 7);
        let name = p.file_name().unwrap().to_str().unwrap();
        assert_eq!(parse_segment_name(name), Some(("rollout", 7)));
        assert_eq!(parse_segment_name("rollout.000007.flog"), Some(("rollout", 7)));
        assert_eq!(parse_segment_name("nodot.flog"), None);
        assert_eq!(parse_segment_name("a.notanumber.flog"), None);
        assert_eq!(parse_segment_name("a.7.other"), None);
        assert_eq!(parse_segment_name(".7.flog"), None);
    }

    #[test]
    fn appends_rotate_at_threshold() {
        let dir = tmp_dir("rotate");
        let mut w = EpisodeLogWriter::create(
            &dir,
            "s",
            WriterConfig { segment_bytes: 256 },
        )
        .unwrap();
        assert_eq!(w.current_seq(), 0);
        for _ in 0..10 {
            w.append(&batch(4)).unwrap();
        }
        assert!(w.current_seq() > 0, "no rotation after 10 oversized appends");
        let (frames, bytes, errors) = w.counters();
        assert_eq!(frames, 10);
        assert!(bytes > 0);
        assert_eq!(errors, 0);
        // Every segment up to current_seq exists on disk.
        for seq in 0..=w.current_seq() {
            assert!(segment_path(&dir, "s", seq).exists(), "segment {seq} missing");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_single_frame_still_written() {
        // A frame larger than segment_bytes must not rotate forever:
        // rotation only happens when the current segment is non-empty.
        let dir = tmp_dir("oversize");
        let mut w = EpisodeLogWriter::create(
            &dir,
            "s",
            WriterConfig { segment_bytes: 8 },
        )
        .unwrap();
        w.append(&batch(16)).unwrap();
        w.append(&batch(16)).unwrap();
        assert_eq!(w.current_seq(), 1); // one rotation, one frame per segment
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recreated_writer_starts_fresh_segment() {
        let dir = tmp_dir("restart");
        let mut w =
            EpisodeLogWriter::create(&dir, "s", WriterConfig::default()).unwrap();
        w.append(&batch(2)).unwrap();
        drop(w);
        let w2 =
            EpisodeLogWriter::create(&dir, "s", WriterConfig::default()).unwrap();
        assert_eq!(w2.current_seq(), 1, "restart must not reuse segment 0");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn two_writers_one_stream_refused() {
        let dir = tmp_dir("collide");
        let _w =
            EpisodeLogWriter::create(&dir, "s", WriterConfig::default()).unwrap();
        // Manually force the same seq: create() itself always advances,
        // so collide by pre-creating the next segment file.
        std::fs::write(segment_path(&dir, "t", 0), b"").unwrap();
        let mut w =
            EpisodeLogWriter::create(&dir, "t", WriterConfig::default()).unwrap();
        assert_eq!(w.current_seq(), 1);
        w.append(&batch(1)).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
