//! artifacts/manifest.json — the ABI between aot.py and the rust runtime.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{Context, Result};
use crate::util::Json;

/// Build-time configuration baked into the artifacts (shapes and
/// numerics the rust side must match — e.g. GAE gamma/lambda).
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub obs_dim: usize,
    pub num_actions: usize,
    pub hidden: Vec<usize>,
    pub inf_batch: usize,
    pub a2c_train_batch: usize,
    pub fragment: usize,
    pub ppo_minibatch: usize,
    pub dqn_minibatch: usize,
    pub impala_t: usize,
    pub impala_b: usize,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub ppo_clip: f32,
    pub pg_param_size: usize,
    pub dqn_param_size: usize,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct InitEntry {
    pub file: String,
    pub len: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: RunConfig,
    pub executables: BTreeMap<String, ExeSpec>,
    pub init_pg: InitEntry,
    pub init_dqn: InitEntry,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text).context("parsing manifest.json")
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let c = j.get("config")?;
        let config = RunConfig {
            obs_dim: c.get("obs_dim")?.as_usize()?,
            num_actions: c.get("num_actions")?.as_usize()?,
            hidden: c
                .get("hidden")?
                .as_arr()?
                .iter()
                .map(|h| h.as_usize())
                .collect::<Result<_>>()?,
            inf_batch: c.get("inf_batch")?.as_usize()?,
            a2c_train_batch: c.get("a2c_train_batch")?.as_usize()?,
            fragment: c.get("fragment")?.as_usize()?,
            ppo_minibatch: c.get("ppo_minibatch")?.as_usize()?,
            dqn_minibatch: c.get("dqn_minibatch")?.as_usize()?,
            impala_t: c.get("impala_t")?.as_usize()?,
            impala_b: c.get("impala_b")?.as_usize()?,
            gamma: c.get("gamma")?.as_f32()?,
            gae_lambda: c.get("gae_lambda")?.as_f32()?,
            ppo_clip: c.get("ppo_clip")?.as_f32()?,
            pg_param_size: c.get("pg_param_size")?.as_usize()?,
            dqn_param_size: c.get("dqn_param_size")?.as_usize()?,
        };
        let mut executables = BTreeMap::new();
        for (name, e) in j.get("executables")?.as_obj()? {
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(TensorSpec {
                        name: i.get("name")?.as_str()?.to_string(),
                        shape: i
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_i64())
                            .collect::<Result<_>>()?,
                        dtype: i.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|o| Ok(o.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            executables.insert(
                name.clone(),
                ExeSpec {
                    file: e.get("file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        let init = |key: &str| -> Result<InitEntry> {
            let e = j.get(key)?;
            Ok(InitEntry {
                file: e.get("file")?.as_str()?.to_string(),
                len: e.get("len")?.as_usize()?,
            })
        };
        Ok(Manifest {
            config,
            executables,
            init_pg: init("init_pg")?,
            init_dqn: init("init_dqn")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {
        "obs_dim": 4, "num_actions": 2, "hidden": [64, 64],
        "inf_batch": 8, "a2c_train_batch": 256, "fragment": 64,
        "ppo_minibatch": 128, "dqn_minibatch": 64,
        "impala_t": 20, "impala_b": 8,
        "gamma": 0.99, "gae_lambda": 0.95, "ppo_clip": 0.2,
        "pg_param_size": 4675, "dqn_param_size": 4610
      },
      "executables": {
        "pg_fwd": {
          "file": "pg_fwd.hlo.txt",
          "inputs": [
            {"name": "params", "shape": [4675], "dtype": "f32"},
            {"name": "obs", "shape": [8, 4], "dtype": "f32"}
          ],
          "outputs": ["logits", "value"]
        }
      },
      "init_pg": {"file": "init_pg.bin", "len": 4675},
      "init_dqn": {"file": "init_dqn.bin", "len": 4610}
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config.obs_dim, 4);
        assert_eq!(m.config.gamma, 0.99);
        assert_eq!(m.config.hidden, vec![64, 64]);
        let exe = &m.executables["pg_fwd"];
        assert_eq!(exe.inputs[1].shape, vec![8, 4]);
        assert_eq!(exe.inputs[1].name, "obs");
        assert_eq!(exe.outputs, vec!["logits", "value"]);
        assert_eq!(m.init_pg.len, 4675);
        assert_eq!(m.init_dqn.file, "init_dqn.bin");
    }

    #[test]
    fn unknown_extra_fields_tolerated() {
        let with_extra =
            SAMPLE.replace("\"init_dqn\"", "\"extra\": [1, 2], \"init_dqn\"");
        assert!(Manifest::parse(&with_extra).is_ok());
    }

    #[test]
    fn missing_config_key_is_error() {
        let broken = SAMPLE.replace("\"gamma\"", "\"gamma_oops\"");
        let err = Manifest::parse(&broken).unwrap_err();
        assert!(format!("{err:#}").contains("gamma"));
    }
}
