//! PJRT runtime bridge: load the JAX/Pallas AOT artifacts (HLO text) and
//! execute them from the rust hot path.
//!
//! `make artifacts` (python, build-time only) writes:
//!   * `artifacts/<name>.hlo.txt` — HLO text per computation (text, not
//!     serialized proto: xla_extension 0.5.1 rejects jax>=0.5's 64-bit
//!     instruction ids; the text parser reassigns them);
//!   * `artifacts/manifest.json` — input/output ABI per computation;
//!   * `artifacts/init_{pg,dqn}.bin` — initial flat parameter vectors.
//!
//! `XlaRuntime` compiles a chosen subset of computations on a
//! `PjRtClient::cpu()`.  PJRT client handles are not `Send` (the crate
//! wraps an `Rc`), so each actor builds its own runtime inside its actor
//! thread — see `actor::ActorHandle::spawn`.

mod manifest;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::util::error::{Context, Result};
use crate::xla;

pub use manifest::{ExeSpec, Manifest, RunConfig, TensorSpec};

/// An argument tensor for an executable call.
pub enum TensorArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
}

impl TensorArg<'_> {
    fn len(&self) -> usize {
        match self {
            TensorArg::F32(v) => v.len(),
            TensorArg::I32(v) => v.len(),
            TensorArg::ScalarF32(_) => 1,
        }
    }

    fn dtype(&self) -> &'static str {
        match self {
            TensorArg::F32(_) | TensorArg::ScalarF32(_) => "f32",
            TensorArg::I32(_) => "i32",
        }
    }
}

/// A compiled computation plus its manifest ABI.
pub struct CompiledExe {
    exe: xla::PjRtLoadedExecutable,
    spec: ExeSpec,
    name: String,
}

impl CompiledExe {
    /// Execute with positional args; validates shape/dtype against the
    /// manifest, returns the output tuple as f32 vectors (all artifact
    /// outputs are f32).
    pub fn run(&self, args: &[TensorArg]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                args.len()
            ));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.spec.inputs) {
            let expected: usize = spec.shape.iter().product::<i64>() as usize;
            if arg.len() != expected {
                return Err(anyhow!(
                    "{}: input '{}' expected {} elements {:?}, got {}",
                    self.name, spec.name, expected, spec.shape, arg.len()
                ));
            }
            if arg.dtype() != spec.dtype {
                return Err(anyhow!(
                    "{}: input '{}' expected dtype {}, got {}",
                    self.name, spec.name, spec.dtype, arg.dtype()
                ));
            }
            // Single-copy literal creation (perf: `vec1().reshape()`
            // copies twice — see EXPERIMENTS.md §Perf O1).
            let dims: Vec<usize> =
                spec.shape.iter().map(|d| *d as usize).collect();
            let lit = match arg {
                TensorArg::F32(v) => {
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        &dims,
                        bytes_of_f32(v),
                    )?
                }
                TensorArg::I32(v) => {
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        &dims,
                        bytes_of_i32(v),
                    )?
                }
                TensorArg::ScalarF32(v) => xla::Literal::scalar(*v),
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            ));
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    pub fn spec(&self) -> &ExeSpec {
        &self.spec
    }
}

fn bytes_of_f32(v: &[f32]) -> &[u8] {
    // Safety: f32 has no invalid bit patterns and alignment of u8 is 1.
    unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
    }
}

fn bytes_of_i32(v: &[i32]) -> &[u8] {
    // Safety: as above.
    unsafe {
        std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
    }
}

/// A PJRT client plus a set of compiled computations, owned by one actor
/// thread.
pub struct XlaRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    exes: BTreeMap<String, CompiledExe>,
    pub manifest: Manifest,
    dir: PathBuf,
}

impl XlaRuntime {
    /// Load the manifest and compile the named computations.
    pub fn load(artifacts_dir: impl AsRef<Path>, names: &[&str]) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .context("loading artifacts manifest (run `make artifacts`)")?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for &name in names {
            let spec = manifest
                .executables
                .get(name)
                .ok_or_else(|| anyhow!("no executable '{name}' in manifest"))?
                .clone();
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert(
                name.to_string(),
                CompiledExe { exe, spec, name: name.to_string() },
            );
        }
        Ok(XlaRuntime { client, exes, manifest, dir })
    }

    pub fn exe(&self, name: &str) -> &CompiledExe {
        self.exes
            .get(name)
            .unwrap_or_else(|| panic!("executable '{name}' not loaded"))
    }

    /// Read an initial flat parameter vector (`init_pg` / `init_dqn`).
    pub fn load_init_params(&self, which: &str) -> Result<Vec<f32>> {
        let entry = match which {
            "init_pg" => &self.manifest.init_pg,
            "init_dqn" => &self.manifest.init_dqn,
            other => return Err(anyhow!("unknown init params '{other}'")),
        };
        let bytes = std::fs::read(self.dir.join(&entry.file))?;
        if bytes.len() != entry.len * 4 {
            return Err(anyhow!(
                "{}: expected {} bytes, got {}",
                entry.file,
                entry.len * 4,
                bytes.len()
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Default artifacts directory: $FLOWRL_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("FLOWRL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_arg_reports_len_and_dtype() {
        assert_eq!(TensorArg::F32(&[1.0, 2.0]).len(), 2);
        assert_eq!(TensorArg::I32(&[1]).dtype(), "i32");
        assert_eq!(TensorArg::ScalarF32(3.0).len(), 1);
        assert_eq!(TensorArg::ScalarF32(3.0).dtype(), "f32");
    }

    #[test]
    fn missing_artifacts_dir_errors() {
        let err = match XlaRuntime::load("/nonexistent/nowhere", &[]) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("manifest"));
    }
}
