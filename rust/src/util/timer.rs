//! `TimerStat` — the paper's timing primitive (Listing A2/A4 use it to
//! instrument the low-level baselines; the flow implementations get the
//! same numbers from `StandardMetricsReporting`).

use std::time::{Duration, Instant};

/// Accumulates wall-clock spans plus a units-processed counter, exposing
/// mean span and throughput — a direct port of RLlib's `TimerStat`.
#[derive(Debug, Default, Clone)]
pub struct TimerStat {
    total: Duration,
    count: u64,
    units: f64,
}

impl TimerStat {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure, accumulating its span.
    pub fn time<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let r = f();
        self.push(start.elapsed());
        r
    }

    pub fn push(&mut self, span: Duration) {
        self.total += span;
        self.count += 1;
    }

    pub fn push_units_processed(&mut self, units: f64) {
        self.units += units;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }

    /// Units per second across all recorded spans.
    pub fn throughput(&self) -> f64 {
        let secs = self.total.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.units / secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_timer_is_zero() {
        let t = TimerStat::new();
        assert_eq!(t.mean(), Duration::ZERO);
        assert_eq!(t.throughput(), 0.0);
        assert_eq!(t.count(), 0);
    }

    #[test]
    fn accumulates_spans_and_units() {
        let mut t = TimerStat::new();
        t.push(Duration::from_millis(10));
        t.push(Duration::from_millis(30));
        t.push_units_processed(100.0);
        assert_eq!(t.count(), 2);
        assert_eq!(t.mean(), Duration::from_millis(20));
        let tput = t.throughput();
        assert!((tput - 2500.0).abs() < 1.0, "tput={tput}");
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = TimerStat::new();
        let v = t.time(|| 42);
        assert_eq!(v, 42);
        assert_eq!(t.count(), 1);
    }
}
