//! Small shared utilities: deterministic RNG, timers, moving statistics,
//! and the vendored error type (`anyhow` stand-in for the offline build).

mod backoff;
pub mod error;
pub mod json;
mod rng;
mod stats;
mod timer;

pub use backoff::Backoff;
pub use json::Json;
pub use rng::Rng;
pub use stats::MovingStat;
pub use timer::TimerStat;
