//! Small shared utilities: deterministic RNG, timers, moving statistics.

pub mod json;
mod rng;
mod stats;
mod timer;

pub use json::Json;
pub use rng::Rng;
pub use stats::MovingStat;
pub use timer::TimerStat;
