//! Windowed moving statistics for episode metrics (mean reward / length
//! over the last N episodes, RLlib-style `episode_reward_mean`).

use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct MovingStat {
    window: usize,
    values: VecDeque<f64>,
    lifetime_count: u64,
}

impl MovingStat {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        MovingStat { window, values: VecDeque::new(), lifetime_count: 0 }
    }

    pub fn push(&mut self, v: f64) {
        if self.values.len() == self.window {
            self.values.pop_front();
        }
        self.values.push_back(v);
        self.lifetime_count += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NAN, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NAN, f64::min)
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn lifetime_count(&self) -> u64 {
        self.lifetime_count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stat_is_nan() {
        let s = MovingStat::new(4);
        assert!(s.mean().is_nan());
        assert!(s.is_empty());
    }

    #[test]
    fn mean_over_window_only() {
        let mut s = MovingStat::new(2);
        s.push(1.0);
        s.push(3.0);
        assert_eq!(s.mean(), 2.0);
        s.push(5.0); // evicts 1.0
        assert_eq!(s.mean(), 4.0);
        assert_eq!(s.len(), 2);
        assert_eq!(s.lifetime_count(), 3);
    }

    #[test]
    fn min_max_track_window() {
        let mut s = MovingStat::new(3);
        for v in [5.0, 1.0, 9.0] {
            s.push(v);
        }
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 9.0);
        s.push(2.0); // evicts 5.0
        assert_eq!(s.max(), 9.0);
        s.push(3.0); // evicts 1.0
        assert_eq!(s.min(), 2.0);
    }
}
