//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build environment cannot fetch crates, so the handful of
//! fallible modules (manifest/JSON parsing, checkpointing, the PJRT
//! runtime bridge) program against this ~100-line shim instead: a
//! string-chained [`Error`], a [`Result`] alias, a [`Context`] extension
//! trait, and the [`anyhow!`]/[`bail!`] macros.  The API subset matches
//! `anyhow` closely enough that swapping the real crate back in is a
//! one-line import change per module.
//!
//! [`anyhow!`]: crate::anyhow!
//! [`bail!`]: crate::bail!

use std::fmt;

/// A boxed-string error with a flattened context chain.
///
/// `anyhow::Error` keeps sources as a linked chain; for our purposes the
/// chain is only ever *displayed*, so contexts are folded eagerly into
/// one message joined by `": "` — which is exactly what `{:#}` prints on
/// the real thing.
pub struct Error {
    msg: String,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything displayable (the `anyhow::Error::msg`
    /// entry point; the `anyhow!` macro routes here).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (`"{context}: {self}"`).
    pub fn context(self, context: impl fmt::Display) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{}` and `{:#}` both print the full chain (the shim flattens
        // contexts at construction time).
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?` — mirrors anyhow's blanket From.
// (Error itself deliberately does NOT implement std::error::Error, so
// this impl cannot overlap with the reflexive `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Context` — attach context to the error arm of a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// `anyhow::anyhow!` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// `anyhow::bail!` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/flowrl/nowhere")
            .context("reading nowhere")?;
        Ok(s)
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(format!("{e}"), "bad thing at 7");
        assert_eq!(format!("{e:#}"), "bad thing at 7");
        assert_eq!(format!("{e:?}"), "bad thing at 7");
    }

    #[test]
    fn bail_early_returns() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{}", f(-1).unwrap_err()).contains("negative"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().unwrap_err();
        let msg = format!("{e}");
        assert!(msg.starts_with("reading nowhere: "), "{msg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<f32> {
            let v: f32 = "not a number".parse()?;
            Ok(v)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_shim_errors_too() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");
    }
}
