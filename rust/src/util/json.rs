//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! The build environment vendors no external crates (no serde), so the
//! manifest ABI is parsed with this ~150-line recursive-descent parser.
//! Supports the full JSON grammar except exotic escapes (\uXXXX
//! surrogate pairs are passed through verbatim).

use std::collections::BTreeMap;

use crate::util::error::Result;
use crate::{anyhow, bail};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => {
                m.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
            }
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            // Pass through unparsed (not needed for the
                            // manifest, which is ASCII).
                            s.push_str("\\u");
                        }
                        c => bail!("bad escape '\\{}'", c as char),
                    }
                }
                _ => s.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit()
                || b == b'-'
                || b == b'+'
                || b == b'.'
                || b == b'e'
                || b == b'E'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow!("bad number '{text}' at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse(r#""hi\nthere""#).unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parses_nested_structures() {
        let j = Json::parse(
            r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}, "f": []}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
        assert_eq!(j.get("d").unwrap().get("e").unwrap(), &Json::Bool(false));
        assert!(j.get("f").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 7, "neg": -2, "frac": 1.5}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 7);
        assert_eq!(j.get("neg").unwrap().as_i64().unwrap(), -2);
        assert!(j.get("neg").unwrap().as_usize().is_err());
        assert!(j.get("frac").unwrap().as_i64().is_err());
        assert_eq!(j.get("frac").unwrap().as_f32().unwrap(), 1.5);
        assert!(j.get("missing").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"a\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
