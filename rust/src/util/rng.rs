//! xoshiro256++ RNG — deterministic, seedable, dependency-free.
//!
//! RL rollouts need per-worker deterministic randomness (env resets,
//! exploration, replay sampling) that is reproducible across runs; a
//! small local generator also keeps the rollout hot loop free of any
//! shared state or locks.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalized weights (all >= 0, sum > 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Rng::new(4);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| rng.uniform()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_covers_range() {
        let mut rng = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut rng = Rng::new(6);
        for _ in 0..1000 {
            let i = rng.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn weighted_frequency_tracks_weights() {
        let mut rng = Rng::new(8);
        let mut counts = [0usize; 3];
        let n = 60_000;
        for _ in 0..n {
            counts[rng.weighted(&[1.0, 2.0, 3.0])] += 1;
        }
        let f = |c: usize| c as f64 / n as f64;
        assert!((f(counts[0]) - 1.0 / 6.0).abs() < 0.02);
        assert!((f(counts[1]) - 2.0 / 6.0).abs() < 0.02);
        assert!((f(counts[2]) - 3.0 / 6.0).abs() < 0.02);
    }
}
