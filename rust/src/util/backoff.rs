//! Bounded exponential backoff — the shared retry-pacing helper.
//!
//! Two very different consumers share this: the `RestartPolicy` on
//! `WorkerSet` paces *restart attempts* of a crash-looping slot with it
//! (non-blocking: the policy records the next-eligible instant and skips
//! the slot until then), and the replay-read operator paces its
//! not-ready polls with it (blocking: the driver sleeps the returned
//! delay).  Keeping one implementation means the breaker tests and the
//! replay tests exercise the same arithmetic.

use std::time::Duration;

/// Exponential backoff with a cap: delays run `base, 2*base, 4*base, …`
/// saturating at `cap`.  `reset()` returns to `base` (call it on
/// success so one transient stall does not leave the consumer slow).
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
}

impl Backoff {
    /// `base` is the first delay, `cap` the saturation bound.  A zero
    /// `base` is clamped to 1µs so doubling makes progress.
    pub fn new(base: Duration, cap: Duration) -> Self {
        let base = base.max(Duration::from_micros(1));
        Backoff { base, cap: cap.max(base), attempt: 0 }
    }

    /// The next delay in the schedule (advances the attempt counter).
    pub fn next_delay(&mut self) -> Duration {
        let delay = self.peek();
        self.attempt = self.attempt.saturating_add(1);
        delay
    }

    /// The delay `next_delay` would return, without advancing.
    pub fn peek(&self) -> Duration {
        // base * 2^attempt, saturating at cap without overflow: once
        // the shift alone exceeds cap/base, further doubling is moot.
        let factor = 1u32.checked_shl(self.attempt).unwrap_or(u32::MAX);
        self.base.saturating_mul(factor).min(self.cap)
    }

    /// Attempts taken since construction or the last `reset`.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Back to the base delay (the consumer made progress).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doubles_until_cap() {
        let mut b = Backoff::new(
            Duration::from_millis(10),
            Duration::from_millis(55),
        );
        assert_eq!(b.next_delay(), Duration::from_millis(10));
        assert_eq!(b.next_delay(), Duration::from_millis(20));
        assert_eq!(b.next_delay(), Duration::from_millis(40));
        // 80ms would exceed the cap: saturate.
        assert_eq!(b.next_delay(), Duration::from_millis(55));
        assert_eq!(b.next_delay(), Duration::from_millis(55));
        assert_eq!(b.attempts(), 5);
    }

    #[test]
    fn reset_returns_to_base() {
        let mut b =
            Backoff::new(Duration::from_millis(5), Duration::from_secs(1));
        b.next_delay();
        b.next_delay();
        b.reset();
        assert_eq!(b.attempts(), 0);
        assert_eq!(b.next_delay(), Duration::from_millis(5));
    }

    #[test]
    fn zero_base_is_clamped() {
        let mut b = Backoff::new(Duration::ZERO, Duration::from_millis(1));
        assert!(b.next_delay() > Duration::ZERO);
    }

    #[test]
    fn huge_attempt_counts_do_not_overflow() {
        let mut b =
            Backoff::new(Duration::from_millis(1), Duration::from_secs(2));
        for _ in 0..100 {
            assert!(b.next_delay() <= Duration::from_secs(2));
        }
        assert_eq!(b.peek(), Duration::from_secs(2));
    }

    #[test]
    fn peek_does_not_advance() {
        let b = Backoff::new(Duration::from_millis(3), Duration::from_secs(1));
        assert_eq!(b.peek(), Duration::from_millis(3));
        assert_eq!(b.peek(), Duration::from_millis(3));
        assert_eq!(b.attempts(), 0);
    }
}
