//! Learner-side operators: `TrainOneStep`, `ComputeGradients`,
//! `ApplyGradients`, `UpdateTargetNetwork`.

use crate::actor::ActorHandle;
use crate::policy::Gradients;
use crate::rollout::{RolloutWorker, WorkerSet};
use crate::sample_batch::SampleBatch;

use super::TrainItem;

/// `TrainOneStep(workers)`: learn on the local worker, then broadcast
/// fresh weights to the remotes as a **versioned weight cast** through
/// the set's `WeightCaster`: one shared `Arc<[f32]>` (a pointer clone
/// per remote, not a parameter-vector copy), at most one queued apply
/// per remote (superseded versions coalesce), and overloaded remotes
/// are shed instead of blocking the learner.  With `gather_sync`
/// upstream the apply envelopes land before the next round's fetches —
/// barrier semantics.  Hand to `for_each`.
pub fn train_one_step(
    workers: &WorkerSet,
) -> impl FnMut(SampleBatch) -> TrainItem + Send + 'static {
    let local = workers.local.clone();
    let caster = workers.caster();
    move |batch| {
        let steps = batch.len();
        let (stats, weights) = local
            .call(move |w| {
                let stats = w.learn_on_batch(&batch);
                (stats, w.get_weights())
            })
            .expect("learner (local worker) actor died");
        caster.broadcast(weights.into());
        TrainItem::new(stats, steps)
    }
}

/// `ComputeGradients`: a parallel op (runs **on the rollout worker**, by
/// `ParIter::for_each` scheduling) computing gradients against the
/// worker's current policy snapshot.  Hand to `ParIter::for_each`.
pub fn compute_gradients(
) -> impl Fn(&mut RolloutWorker, SampleBatch) -> Gradients + Send + Sync + 'static
{
    |w, batch| w.compute_gradients(&batch)
}

/// `ApplyGradients(workers)`: apply a gathered gradient on the local
/// (learner) worker, then push the new weights back to the worker that
/// produced the gradient (A3C's fine-grained per-worker update — a
/// dotted-arrow actor message, paper Fig. 4).  Hand to `for_each` after
/// `gather_async_with_source`.
pub fn apply_gradients(
    local: ActorHandle<RolloutWorker>,
) -> impl FnMut((Gradients, ActorHandle<RolloutWorker>)) -> TrainItem + Send + 'static
{
    move |(grads, source)| {
        let steps = grads.count;
        let stats = grads.stats.clone();
        let weights = local
            .call(move |w| {
                w.apply_gradients(&grads);
                w.get_weights()
            })
            .expect("learner (local worker) actor died");
        source.cast(move |w| w.set_weights(&weights));
        TrainItem::new(stats, steps)
    }
}

/// `UpdateTargetNetwork(workers, every)`: after every `every` trained
/// steps, sync the learner's target network (DQN family).  Passes items
/// through unchanged.
pub fn update_target_network(
    local: ActorHandle<RolloutWorker>,
    every: usize,
) -> impl FnMut(TrainItem) -> TrainItem + Send + 'static {
    let mut since_update = 0usize;
    move |item| {
        since_update += item.steps_trained;
        if since_update >= every {
            since_update = 0;
            local.cast(|w| w.policy.update_target());
        }
        item
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::spawn_group;
    use crate::env::{DummyEnv, Env};
    use crate::iter::ParIter;
    use crate::ops::parallel_rollouts;
    use crate::policy::DummyPolicy;
    use crate::rollout::{CollectMode, RolloutWorker};

    fn workers(n: usize) -> Vec<ActorHandle<RolloutWorker>> {
        spawn_group("w", n, move |_| {
            Box::new(move || {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    8,
                    CollectMode::OnPolicy,
                )
            })
        })
    }

    fn worker_set(n_remote: usize) -> WorkerSet {
        WorkerSet::new(n_remote, |_| {
            Box::new(|| {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    8,
                    CollectMode::OnPolicy,
                )
            })
        })
    }

    #[test]
    fn train_one_step_updates_local_and_broadcasts() {
        let set = worker_set(2);
        let mut op = train_one_step(&set);
        let batch = set.local.call(|w| w.sample()).unwrap();
        let item = op(batch);
        assert_eq!(item.steps_trained, 8);
        assert!(item.stats.contains_key("loss"));
        let local_w = set.local.call(|w| w.get_weights()).unwrap();
        assert_ne!(local_w, vec![0.0]); // dummy policy moved
        // The versioned cast is queued before these calls (FIFO per
        // mailbox), so by the time a call returns the apply has run.
        for r in set.remotes() {
            assert_eq!(r.call(|w| w.get_weights()).unwrap(), local_w);
        }
        assert_eq!(set.weight_cast_stats().version, 1);
    }

    #[test]
    fn a3c_style_grads_flow_end_to_end() {
        let mut all = workers(3);
        let local = all.remove(0);
        // The paper's A3C plan: rollouts -> ComputeGradients (on
        // workers) -> gather_async -> ApplyGradients (on local).
        let mut apply = apply_gradients(local.clone());
        let mut it = parallel_rollouts(all.clone())
            .for_each(|w, b| compute_gradients()(w, b))
            .gather_async_with_source(1)
            .for_each(move |pair| apply(pair))
            .take(4);
        let mut n = 0;
        while let Some(item) = it.next() {
            assert_eq!(item.steps_trained, 8);
            n += 1;
        }
        assert_eq!(n, 4);
        // Source workers got the updated weights pushed back.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let local_w = local.call(|w| w.get_weights()).unwrap()[0];
        assert_ne!(local_w, 0.0);
        let w0 = all[0].call(|w| w.get_weights()).unwrap()[0];
        assert_ne!(w0, 0.0);
    }

    #[test]
    fn update_target_network_fires_on_threshold() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        // Count target updates via a counting policy.
        struct CountingPolicy(Arc<AtomicUsize>);
        impl crate::policy::Policy for CountingPolicy {
            fn compute_actions(
                &mut self,
                _obs: &[f32],
                n: usize,
            ) -> Vec<crate::policy::ActionOutput> {
                vec![
                    crate::policy::ActionOutput {
                        action: 0,
                        logp: 0.0,
                        value: 0.0
                    };
                    n
                ]
            }
            fn compute_gradients(
                &mut self,
                _b: &SampleBatch,
            ) -> crate::policy::Gradients {
                crate::policy::Gradients {
                    flat: vec![],
                    stats: Default::default(),
                    count: 0,
                }
            }
            fn apply_gradients(&mut self, _g: &crate::policy::Gradients) {}
            fn get_weights(&self) -> Vec<f32> {
                vec![]
            }
            fn set_weights(&mut self, _w: &[f32]) {}
            fn update_target(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let local = ActorHandle::spawn("local", move || {
            let envs: Vec<Box<dyn Env>> = vec![Box::new(DummyEnv::new(4, 10))];
            RolloutWorker::new(
                envs,
                Box::new(CountingPolicy(c)),
                8,
                CollectMode::OnPolicy,
            )
        });
        let mut op = update_target_network(local.clone(), 100);
        for _ in 0..4 {
            // 4 x 30 steps -> fires at 120, then accumulates 0.
            op(TrainItem::new(Default::default(), 30));
        }
        local.call(|_| ()).unwrap(); // drain mailbox
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn compute_gradients_runs_on_worker_state() {
        let ws = workers(1);
        let mut it = ParIter::from_actors(ws, |w| Some(w.sample()))
            .for_each(|w, b| compute_gradients()(w, b))
            .gather_async(1)
            .take(1);
        let grads = it.next().unwrap();
        assert_eq!(grads.count, 8);
        assert_eq!(grads.flat.len(), 1);
    }
}
