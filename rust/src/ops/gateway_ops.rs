//! Gateway-side operators: the elastic external-episode service.
//!
//! [`GatewayService`] runs [`EpisodeGateway`] session tables as
//! registry-backed actors behind a
//! [`WorkerSet`](crate::rollout::WorkerSet) — the same machinery that
//! grows/retires/restarts rollout workers and replay shards applies to
//! the serving tier.  Clients hold a [`GatewaySession`]: a slot lease
//! (shard index + epoch + incarnation id, the `ReplayLease` idiom) plus
//! their [`SessionId`] inside that shard's table, so a request issued
//! against a shard that was restarted or retired under the client's
//! feet resolves to [`SessionError::Expired`] instead of reaching a
//! fresh incarnation whose session slots mean something else.
//!
//! **Batching without a clock.**  A client's `request_action` is a
//! non-blocking `try_cast` of the observation followed by a blocking
//! poll `call`.  The shard's mailbox is FIFO: every observation cast
//! that arrived before the first poll is already queued ahead of it, so
//! the poll's [`EpisodeGateway::tick`] coalesces *all* of them into one
//! flat `[N, obs_dim]` `compute_actions_into` forward.  Under
//! concurrent clients the batch fills itself — no timer, no minimum
//! batch delay, and a lone client still gets served in one round trip.
//!
//! **Load discipline.**  `try_cast` returning `Full` is the mailbox
//! watermark — the request is shed at the client (counted, reported
//! through [`GatewayBacklogStats`]) rather than queued into a stall.
//! Admission sheds and idle-deadline reaping live one layer down in
//! [`EpisodeGateway`]; reaping is driven opportunistically from the
//! serving path, so an idle shard with no traffic reaps on its next
//! experience pump instead.
//!
//! **Serving is sampling.**  Every served episode leaves transitions in
//! the shard's fragment builder; [`gateway_experience`] gathers those
//! fragments through the registry — the experience source the
//! train-from-gateway plan (`algorithms::external_plan`) stores into
//! the replay tier.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::actor::{ShardRegistry, TryCastError};
use crate::env::{
    EpisodeGateway, GatewayBacklogStats, GatewayConfig, GatewayShardStats,
    SessionError, SessionId,
};
use crate::iter::{LocalIter, ParIter};
use crate::metrics::EpisodeRecord;
use crate::policy::{ActionOutput, Policy};
use crate::rollout::{
    RestartPolicy, RestartReport, WorkerMetrics, WorkerSet,
};
use crate::sample_batch::SampleBatch;
use crate::util::Backoff;

/// First delay of the client's action-poll backoff (doubles per empty
/// poll; the first poll almost always succeeds, see the module docs).
pub const DEFAULT_GATEWAY_POLL_BACKOFF_BASE: Duration =
    Duration::from_micros(20);

/// Cap on the client's action-poll backoff.
pub const DEFAULT_GATEWAY_POLL_BACKOFF_CAP: Duration =
    Duration::from_millis(2);

/// First not-ready backoff of [`gateway_experience`].
pub const DEFAULT_GATEWAY_EXPERIENCE_BACKOFF_BASE: Duration =
    Duration::from_micros(200);

/// Cap on [`gateway_experience`]'s not-ready backoff.
pub const DEFAULT_GATEWAY_EXPERIENCE_BACKOFF_CAP: Duration =
    Duration::from_millis(20);

/// Observation casts a client re-issues when its submit was lost (a
/// dropped cast under fault injection) before giving up on the request.
const MAX_SUBMIT_ATTEMPTS: usize = 4;

/// One gateway shard: a session table plus the policy it serves,
/// wrapped for actor residency.  The policy is built *on the actor
/// thread* by the service's factory (policies are deliberately not
/// `Send` — XLA-backed ones hold thread-local runtime state).
pub struct GatewayActorState {
    gateway: EpisodeGateway,
    policy: Box<dyn Policy>,
    gauge: Arc<GatewayShardGauge>,
    /// Shard-local time origin; all deadlines are nanos since spawn.
    start: Instant,
    last_reap_ns: u64,
    /// Actions served since the last metrics drain.
    steps_served: usize,
    /// Optional episode-log sink: every pumped experience fragment is
    /// also appended as one durable frame (`offline` subsystem).
    log_sink: Option<crate::offline::EpisodeLogWriter>,
}

impl GatewayActorState {
    pub fn new(
        cfg: GatewayConfig,
        policy: Box<dyn Policy>,
        gauge: Arc<GatewayShardGauge>,
    ) -> Self {
        let mut state = GatewayActorState {
            gateway: EpisodeGateway::new(cfg),
            policy,
            gauge,
            start: Instant::now(),
            last_reap_ns: 0,
            steps_served: 0,
            log_sink: None,
        };
        // Publish the fresh (empty) table immediately: the gauge is
        // re-attached across restarts, and until the first request
        // lands it would otherwise keep reporting the dead
        // incarnation's sessions/pending — ghost backlog the
        // autoscaler and connect admission would act on.
        state.publish();
        state
    }

    /// Tap this shard's pumped fragments into an episode-log stream
    /// (or detach with `None`).  Append failures are counted on the
    /// writer and never stall the serving path.
    pub fn set_log_sink(
        &mut self,
        sink: Option<crate::offline::EpisodeLogWriter>,
    ) {
        self.log_sink = sink;
    }

    fn now_ns(&self) -> u64 {
        self.start.elapsed().as_nanos() as u64
    }

    /// Opportunistic maintenance on the serving path: batch-serve any
    /// queued requests, and run the idle reaper at half-deadline
    /// cadence (strikes are re-armed per pass, so a faster cadence
    /// would not reap earlier — this just bounds the table scans).
    fn maintain(&mut self) {
        let now = self.now_ns();
        if self.gateway.pending_requests() > 0 {
            self.gateway.tick(&mut *self.policy, now);
        }
        let cadence = self.gateway.config().idle_deadline_ns / 2;
        if now.saturating_sub(self.last_reap_ns) >= cadence {
            self.last_reap_ns = now;
            self.gateway.reap_idle(now);
        }
    }

    fn publish(&mut self) {
        let s = self.gateway.stats();
        self.gauge.publish(&s);
    }

    pub fn start_episode(&mut self) -> Result<SessionId, SessionError> {
        let now = self.now_ns();
        let r = self.gateway.start_episode(now);
        self.publish();
        r
    }

    /// Queue an observation (cast target — errors surface at the next
    /// poll: a shed/expired session answers `Expired` there).
    pub fn submit_obs(&mut self, id: SessionId, obs: &[f32]) {
        let now = self.now_ns();
        let _ = self.gateway.submit_obs(id, obs, now);
    }

    /// Serve queued requests (one batched forward) and report `id`'s
    /// action.  `Ok(None)` = the request is queued but not yet served —
    /// in practice only when the submit cast itself was lost.
    pub fn poll(
        &mut self,
        id: SessionId,
    ) -> Result<Option<ActionOutput>, SessionError> {
        self.maintain();
        let now = self.now_ns();
        let r = self.gateway.take_action(id, now);
        if matches!(r, Ok(Some(_))) {
            self.steps_served += 1;
        }
        self.publish();
        r
    }

    pub fn log_reward(&mut self, id: SessionId, reward: f32) {
        let now = self.now_ns();
        let _ = self.gateway.log_reward(id, reward, now);
    }

    pub fn end_episode(
        &mut self,
        id: SessionId,
        final_obs: Option<Vec<f32>>,
    ) -> Result<EpisodeRecord, SessionError> {
        let now = self.now_ns();
        let r = self.gateway.end_episode(id, final_obs.as_deref(), now);
        self.publish();
        r
    }

    pub fn set_weights(&mut self, weights: &[f32]) {
        self.policy.set_weights(weights);
    }

    /// Maintenance + fragment drain — the experience pump's per-shard
    /// step.  Ticking here also serves requests whose client died
    /// between submit and poll, so they cannot pin the pending queue.
    pub fn pump_fragment(&mut self) -> Option<SampleBatch> {
        self.maintain();
        let frag = self.gateway.drain_fragment();
        if let (Some(sink), Some(batch)) =
            (self.log_sink.as_mut(), frag.as_ref())
        {
            let _ = sink.append(batch);
        }
        self.publish();
        frag
    }

    /// Direct table access for tests.
    pub fn gateway_mut(&mut self) -> &mut EpisodeGateway {
        &mut self.gateway
    }
}

impl WorkerMetrics for GatewayActorState {
    fn drain_metrics(&mut self) -> (Vec<EpisodeRecord>, usize) {
        let eps = self.gateway.drain_episodes();
        let steps = std::mem::take(&mut self.steps_served);
        (eps, steps)
    }
}

/// Lock-free per-slot gauge the shard publishes its table stats into —
/// read by [`GatewayService::backlog_stats`] without queueing a call
/// behind the very backlog being measured (the `ReplayShardGauge`
/// idiom).  Re-attached to every incarnation spawned into the slot.
#[derive(Debug, Default)]
pub struct GatewayShardGauge {
    pub sessions: AtomicU64,
    pub pending: AtomicU64,
    pub started: AtomicU64,
    pub shed: AtomicU64,
    pub reaped: AtomicU64,
    pub completed: AtomicU64,
    pub ticks: AtomicU64,
    pub batched_rows: AtomicU64,
    pub max_batch_fill: AtomicU64,
    /// p99 action latency in microseconds, stored as `f64` bits.
    pub p99_us_bits: AtomicU64,
    pub transitions: AtomicU64,
}

impl GatewayShardGauge {
    fn publish(&self, s: &GatewayShardStats) {
        self.sessions.store(s.live_sessions as u64, Relaxed);
        self.pending.store(s.pending_requests as u64, Relaxed);
        self.started.store(s.started, Relaxed);
        self.shed.store(s.shed, Relaxed);
        self.reaped.store(s.reaped, Relaxed);
        self.completed.store(s.completed, Relaxed);
        self.ticks.store(s.ticks, Relaxed);
        self.batched_rows.store(s.batched_rows, Relaxed);
        self.max_batch_fill.store(s.max_batch_fill, Relaxed);
        self.p99_us_bits
            .store(s.p99_action_latency_us.to_bits(), Relaxed);
        self.transitions.store(s.transitions, Relaxed);
    }

    pub fn p99_us(&self) -> f64 {
        f64::from_bits(self.p99_us_bits.load(Relaxed))
    }
}

/// Service-scoped lifetime counters (survive shard churn, so backlog
/// rates stay monotone — the `ReplayCounters` idiom).
#[derive(Debug, Default)]
pub struct GatewayCounters {
    /// Sessions opened through [`GatewayService::connect`].
    pub connects: AtomicU64,
    /// Connect attempts shed: every live shard at its admission
    /// watermark, or no live shard at all.
    pub connect_shed: AtomicU64,
    /// Observation casts shed by mailbox backpressure (`try_cast` Full).
    pub casts_shed: AtomicU64,
    /// Actions delivered to clients.
    pub actions: AtomicU64,
    /// Requests that found their shard restarted/retired (lease epoch
    /// or incarnation mismatch) — the session is gone with it.
    pub sessions_lost: AtomicU64,
    /// Experience fragments yielded by [`gateway_experience`].
    pub fragments: AtomicU64,
}

/// The elastic serving tier: [`EpisodeGateway`] shards in a
/// [`ShardRegistry`]-backed [`WorkerSet`], shared traffic counters, and
/// per-slot gauges.  Cloning shares all state.
#[derive(Clone)]
pub struct GatewayService {
    set: WorkerSet<GatewayActorState>,
    counters: Arc<GatewayCounters>,
    gauges: Arc<Mutex<Vec<Arc<GatewayShardGauge>>>>,
    /// Round-robin cursor for connect routing.
    session_seq: Arc<AtomicU64>,
}

impl GatewayService {
    /// Spawn `num_shards` gateway shards (named `gateway-{i}`), each
    /// serving a policy built by `make_policy(slot)` **on the shard's
    /// thread**.  The set's local slot is a zero-traffic sentinel (the
    /// `with_protocol` learner slot).  The sync protocol is a no-op: a
    /// restarted shard rejoins with a factory-fresh policy and an empty
    /// table — its sessions are gone by design (clients hold leases and
    /// observe `Expired`), and its weights catch up on the next
    /// [`GatewayService::push_weights`].
    pub fn new(
        num_shards: usize,
        cfg: GatewayConfig,
        make_policy: impl Fn(usize) -> Box<dyn Policy> + Send + Sync + 'static,
    ) -> Self {
        assert!(num_shards >= 1, "gateway service needs at least one shard");
        let make_policy: Arc<
            dyn Fn(usize) -> Box<dyn Policy> + Send + Sync,
        > = Arc::new(make_policy);
        let gauges: Arc<Mutex<Vec<Arc<GatewayShardGauge>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let factory_gauges = gauges.clone();
        let set = WorkerSet::with_protocol(
            "gateway-local",
            "gateway",
            num_shards,
            move |i| {
                let cfg = cfg.clone();
                let make_policy = make_policy.clone();
                if i == 0 {
                    // Local sentinel: liveness probes only.
                    return Box::new(move || {
                        GatewayActorState::new(
                            GatewayConfig { max_sessions: 1, ..cfg },
                            make_policy(usize::MAX),
                            Arc::new(GatewayShardGauge::default()),
                        )
                    });
                }
                let slot = i - 1;
                let gauge = {
                    let mut g = factory_gauges.lock().unwrap();
                    while g.len() <= slot {
                        g.push(Arc::new(GatewayShardGauge::default()));
                    }
                    g[slot].clone()
                };
                Box::new(move || {
                    GatewayActorState::new(cfg, make_policy(slot), gauge)
                })
            },
            // No sync protocol — see the constructor docs.
            |_local, _fresh| Ok(()),
        );
        GatewayService {
            set,
            counters: Arc::new(GatewayCounters::default()),
            gauges,
            session_seq: Arc::new(AtomicU64::new(0)),
        }
    }

    /// The underlying elastic set (registry, scale/fault counters,
    /// restart machinery, metrics drain).
    pub fn set(&self) -> &WorkerSet<GatewayActorState> {
        &self.set
    }

    pub fn registry(&self) -> &ShardRegistry<GatewayActorState> {
        self.set.registry()
    }

    pub fn counters(&self) -> Arc<GatewayCounters> {
        self.counters.clone()
    }

    pub fn num_live_shards(&self) -> usize {
        self.registry().num_live()
    }

    /// Scale the live shard count to exactly `n` under live client
    /// traffic (delegates to `WorkerSet::scale_to`).  Sessions on a
    /// retired shard observe `Expired` through their leases.
    pub fn scale_to(
        &self,
        n: usize,
    ) -> crate::util::error::Result<(Vec<usize>, Vec<usize>)> {
        self.set.scale_to(n)
    }

    /// Respawn crashed shards under a [`RestartPolicy`].  Replacements
    /// rejoin with empty tables under a new epoch; stale sessions are
    /// fenced by their leases.
    pub fn restart_dead_with_policy(
        &self,
        policy: &RestartPolicy,
    ) -> RestartReport {
        self.set.restart_dead_with_policy(policy)
    }

    /// Open an episode on a live shard, round-robin over the live slot
    /// set.  A shard at its admission watermark is skipped; when every
    /// live shard sheds (or none is live), the connect itself is shed.
    pub fn connect(&self) -> Result<GatewaySession, SessionError> {
        let registry = self.registry();
        let live = registry.live_indices();
        if live.is_empty() {
            self.counters.connect_shed.fetch_add(1, Relaxed);
            return Err(SessionError::Shed);
        }
        let cursor = self.session_seq.fetch_add(1, Relaxed) as usize;
        for k in 0..live.len() {
            let slot = live[(cursor + k) % live.len()];
            let Some((handle, epoch)) = registry.get_live(slot) else {
                continue;
            };
            match handle.call(|ga| ga.start_episode()) {
                Ok(Ok(id)) => {
                    self.counters.connects.fetch_add(1, Relaxed);
                    return Ok(GatewaySession {
                        registry: registry.clone(),
                        shard_idx: slot,
                        epoch,
                        incarnation: handle.id(),
                        id,
                        counters: self.counters.clone(),
                    });
                }
                // Shed or (rare) expired table state: try the next
                // shard.  A dead shard likewise.
                Ok(Err(_)) | Err(_) => continue,
            }
        }
        self.counters.connect_shed.fetch_add(1, Relaxed);
        Err(SessionError::Shed)
    }

    /// Broadcast fresh policy weights to every live shard,
    /// non-blocking: a shard whose mailbox is full keeps serving on its
    /// current weights and catches the next push (weight freshness must
    /// never stall the serving path).
    pub fn push_weights(&self, weights: Arc<[f32]>) {
        let registry = self.registry();
        for i in registry.live_indices() {
            if let Some((handle, _)) = registry.get_live(i) {
                let w = weights.clone();
                let _ = handle.try_cast(move |ga| ga.set_weights(&w));
            }
        }
    }

    /// Point-in-time backlog telemetry over the live shards — session
    /// and pending-request load from the slot gauges (lock-free),
    /// mailbox depths from actor telemetry, lifetime traffic from the
    /// service counters.  Attached to `TrainResult::gateway` and fed to
    /// `Autoscaler::gateway_signals`.
    pub fn backlog_stats(&self) -> GatewayBacklogStats {
        let registry = self.registry();
        let gauges = self.gauges.lock().unwrap();
        let mut out = GatewayBacklogStats {
            slots: registry.len(),
            ..Default::default()
        };
        for i in registry.live_indices() {
            let Some((handle, _epoch)) = registry.get_live(i) else {
                continue;
            };
            out.live_shards += 1;
            let s = handle.stats();
            out.max_queue_len = out.max_queue_len.max(s.queue_len);
            out.max_queue_hwm = out.max_queue_hwm.max(s.queue_hwm);
            if let Some(g) = gauges.get(i) {
                out.sessions += g.sessions.load(Relaxed) as usize;
                out.pending += g.pending.load(Relaxed) as usize;
                out.started += g.started.load(Relaxed);
                out.shed += g.shed.load(Relaxed);
                out.reaped += g.reaped.load(Relaxed);
                out.completed += g.completed.load(Relaxed);
                out.ticks += g.ticks.load(Relaxed);
                out.batched_rows += g.batched_rows.load(Relaxed);
                out.max_batch_fill =
                    out.max_batch_fill.max(g.max_batch_fill.load(Relaxed));
                out.p99_action_latency_us =
                    out.p99_action_latency_us.max(g.p99_us());
                out.transitions += g.transitions.load(Relaxed);
            }
        }
        // Mailbox backpressure and failed connects are sheds too: the
        // autoscaler must see load the shards never admitted.
        out.shed += self.counters.casts_shed.load(Relaxed)
            + self.counters.connect_shed.load(Relaxed);
        out
    }
}

/// Spawn an elastic gateway tier — the dataflow-facing constructor
/// (the serving twin of `create_replay_shards`).
pub fn create_gateway_shards(
    num_shards: usize,
    cfg: GatewayConfig,
    make_policy: impl Fn(usize) -> Box<dyn Policy> + Send + Sync + 'static,
) -> GatewayService {
    GatewayService::new(num_shards, cfg, make_policy)
}

/// A client's handle to one live episode: the shard lease (slot +
/// epoch + incarnation) plus the session id inside that shard's table.
/// Requests re-resolve the slot through the registry per call, so a
/// shard restarted or retired since connect answers
/// [`SessionError::Expired`] — the client reconnects rather than
/// talking to a stranger's session table.
pub struct GatewaySession {
    registry: ShardRegistry<GatewayActorState>,
    shard_idx: usize,
    epoch: u64,
    incarnation: u64,
    id: SessionId,
    counters: Arc<GatewayCounters>,
}

impl GatewaySession {
    pub fn id(&self) -> SessionId {
        self.id
    }

    pub fn shard_idx(&self) -> usize {
        self.shard_idx
    }

    /// The producing incarnation, if still live under the lease.
    fn resolve(
        &self,
    ) -> Result<crate::actor::ActorHandle<GatewayActorState>, SessionError>
    {
        match self.registry.get_live(self.shard_idx) {
            Some((handle, epoch))
                if epoch == self.epoch
                    && handle.id() == self.incarnation =>
            {
                Ok(handle)
            }
            _ => {
                self.counters.sessions_lost.fetch_add(1, Relaxed);
                Err(SessionError::Expired)
            }
        }
    }

    /// Submit `obs` and block for the served action.  The submit is a
    /// non-blocking cast — a full shard mailbox sheds the request here
    /// ([`SessionError::Shed`], counted) instead of queueing into a
    /// stall.  The poll that follows rides the mailbox-FIFO batching
    /// described in the module docs.
    pub fn request_action(
        &self,
        obs: &[f32],
    ) -> Result<ActionOutput, SessionError> {
        let handle = self.resolve()?;
        let id = self.id;
        for _attempt in 0..MAX_SUBMIT_ATTEMPTS {
            let o = obs.to_vec();
            match handle.try_cast(move |ga| ga.submit_obs(id, &o)) {
                Ok(()) => {}
                Err(TryCastError::Full) => {
                    self.counters.casts_shed.fetch_add(1, Relaxed);
                    return Err(SessionError::Shed);
                }
                Err(TryCastError::Dead) => {
                    self.counters.sessions_lost.fetch_add(1, Relaxed);
                    return Err(SessionError::Expired);
                }
            }
            let mut backoff = Backoff::new(
                DEFAULT_GATEWAY_POLL_BACKOFF_BASE,
                DEFAULT_GATEWAY_POLL_BACKOFF_CAP,
            );
            loop {
                match handle.call(move |ga| ga.poll(id)) {
                    Ok(Ok(Some(action))) => {
                        self.counters.actions.fetch_add(1, Relaxed);
                        return Ok(action);
                    }
                    // Queued but unserved — only possible when another
                    // client's poll raced ours out of the tick; the
                    // next poll serves it.
                    Ok(Ok(None)) => {
                        std::thread::sleep(backoff.next_delay())
                    }
                    // "take before submit": our submit cast was lost
                    // (fault injection / mailbox drop) — re-submit.
                    Ok(Err(SessionError::Protocol(_))) => break,
                    Ok(Err(e)) => return Err(e),
                    Err(_) => {
                        self.counters.sessions_lost.fetch_add(1, Relaxed);
                        return Err(SessionError::Expired);
                    }
                }
            }
        }
        Err(SessionError::Expired)
    }

    /// Log reward earned since the last action (fire-and-forget).
    pub fn log_reward(&self, reward: f32) -> Result<(), SessionError> {
        let handle = self.resolve()?;
        let id = self.id;
        handle.cast(move |ga| ga.log_reward(id, reward));
        Ok(())
    }

    /// Close the episode, consuming the handle.
    pub fn end(
        self,
        final_obs: Option<&[f32]>,
    ) -> Result<EpisodeRecord, SessionError> {
        let handle = self.resolve()?;
        let id = self.id;
        let obs = final_obs.map(|o| o.to_vec());
        match handle.call(move |ga| ga.end_episode(id, obs)) {
            Ok(r) => r,
            Err(_) => {
                self.counters.sessions_lost.fetch_add(1, Relaxed);
                Err(SessionError::Expired)
            }
        }
    }
}

/// `GatewayExperience(service, num_async)`: an endless stream of
/// experience fragments gathered through the shard registry — the
/// transitions served episodes left behind, ready to store into the
/// replay tier.  Shards without a full fragment yield `None` after an
/// exponential backoff (never blocking, so a `Concurrently` composition
/// cannot deadlock on a quiet gateway).
pub fn gateway_experience(
    service: &GatewayService,
    num_async: usize,
) -> LocalIter<Option<SampleBatch>> {
    let counters = service.counters();
    let mut backoff = Backoff::new(
        DEFAULT_GATEWAY_EXPERIENCE_BACKOFF_BASE,
        DEFAULT_GATEWAY_EXPERIENCE_BACKOFF_CAP,
    );
    ParIter::from_registry(
        service.registry().clone(),
        |ga: &mut GatewayActorState| Some(ga.pump_fragment()),
    )
    .gather_async(num_async)
    .for_each(move |maybe| match maybe {
        Some(batch) => {
            backoff.reset();
            counters.fragments.fetch_add(1, Relaxed);
            Some(batch)
        }
        None => {
            std::thread::sleep(backoff.next_delay());
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::DummyPolicy;

    fn service(shards: usize, max_sessions: usize) -> GatewayService {
        create_gateway_shards(
            shards,
            GatewayConfig {
                obs_dim: 4,
                max_sessions,
                idle_deadline_ns: 200_000_000, // 200ms
                forgiveness: 1,
                fragment: 4,
            },
            |_slot| Box::new(DummyPolicy::new(0.1)),
        )
    }

    #[test]
    fn restarted_shard_resets_its_reattached_gauge() {
        // Regression: the gauge is re-attached across shard restarts,
        // and `GatewayActorState::new` must publish the fresh (empty)
        // table immediately — otherwise the gauge keeps reporting the
        // dead incarnation's sessions/pending until the first request
        // lands, and admission/autoscaling act on ghost backlog.
        let cfg = GatewayConfig {
            obs_dim: 4,
            max_sessions: 8,
            idle_deadline_ns: 200_000_000,
            forgiveness: 1,
            fragment: 4,
        };
        let gauge = Arc::new(GatewayShardGauge::default());
        let mut shard = GatewayActorState::new(
            cfg.clone(),
            Box::new(DummyPolicy::new(0.1)),
            gauge.clone(),
        );
        let id = shard.start_episode().unwrap();
        shard.submit_obs(id, &[0.25; 4]);
        let _ = shard.poll(id);
        assert_eq!(gauge.sessions.load(Relaxed), 1);
        // Simulate the restart path: the slot spawns a fresh
        // incarnation and re-attaches the same gauge.
        drop(shard);
        let _fresh = GatewayActorState::new(
            cfg,
            Box::new(DummyPolicy::new(0.1)),
            gauge.clone(),
        );
        assert_eq!(
            gauge.sessions.load(Relaxed),
            0,
            "fresh incarnation must not inherit ghost sessions"
        );
        assert_eq!(gauge.pending.load(Relaxed), 0);
    }

    #[test]
    fn session_round_trip_through_the_service() {
        let svc = service(2, 8);
        let session = svc.connect().unwrap();
        for _ in 0..3 {
            let a = session.request_action(&[0.25; 4]).unwrap();
            assert!(a.action == 0 || a.action == 1);
            session.log_reward(1.0).unwrap();
        }
        let rec = session.end(Some(&[0.0; 4])).unwrap();
        assert_eq!(rec.length, 3);
        assert!((rec.reward - 3.0).abs() < 1e-6);
        let stats = svc.backlog_stats();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.sessions, 0);
        assert!(svc.counters().actions.load(Relaxed) >= 3);
    }

    #[test]
    fn connect_round_robins_live_shards() {
        let svc = service(2, 8);
        let sessions: Vec<GatewaySession> =
            (0..4).map(|_| svc.connect().unwrap()).collect();
        let shards: std::collections::BTreeSet<usize> =
            sessions.iter().map(|s| s.shard_idx()).collect();
        assert_eq!(shards.len(), 2, "connects must spread over shards");
        for s in sessions {
            s.end(None).unwrap();
        }
    }

    #[test]
    fn connect_sheds_when_every_shard_is_full() {
        let svc = service(2, 1);
        let held: Vec<GatewaySession> =
            (0..2).map(|_| svc.connect().unwrap()).collect();
        assert!(matches!(svc.connect(), Err(SessionError::Shed)));
        assert!(svc.counters().connect_shed.load(Relaxed) >= 1);
        assert!(svc.backlog_stats().shed >= 1);
        drop(held);
    }

    #[test]
    fn push_weights_reaches_live_shards() {
        let svc = service(2, 8);
        svc.push_weights(vec![42.0].into());
        // try_cast is async — wait for the applies via a barrier call.
        for i in svc.registry().live_indices() {
            let (h, _) = svc.registry().get_live(i).unwrap();
            let w = h.call(|ga| ga.policy.get_weights()).unwrap();
            assert_eq!(w, vec![42.0]);
        }
    }

    #[test]
    fn lease_fences_a_restarted_shard() {
        let svc = service(1, 8);
        let session = svc.connect().unwrap();
        let (shard, epoch0) = svc.registry().get_live(0).unwrap();
        // Kill and restart: new incarnation, bumped epoch.
        assert!(shard.call(|_| -> () { panic!("fault injection") }).is_err());
        assert!(shard.await_poisoned(Duration::from_secs(2)));
        assert_eq!(svc.set().restart_dead(), vec![0]);
        assert!(svc.registry().epoch(0) > epoch0);
        assert!(matches!(
            session.request_action(&[0.0; 4]),
            Err(SessionError::Expired)
        ));
        assert!(svc.counters().sessions_lost.load(Relaxed) >= 1);
        // Fresh connects reach the new incarnation.
        let s2 = svc.connect().unwrap();
        assert!(s2.request_action(&[0.0; 4]).is_ok());
        s2.end(None).unwrap();
    }

    #[test]
    fn experience_stream_yields_serving_transitions() {
        let svc = service(1, 8);
        let session = svc.connect().unwrap();
        // 5 actions + terminal = 5 transitions >= fragment of 4.
        for _ in 0..5 {
            session.request_action(&[0.5; 4]).unwrap();
            session.log_reward(1.0).unwrap();
        }
        session.end(None).unwrap();
        let mut stream = gateway_experience(&svc, 1);
        let batch = loop {
            if let Some(b) = stream.next().unwrap() {
                break b;
            }
        };
        assert!(batch.len() >= 4);
        assert_eq!(svc.counters().fragments.load(Relaxed), 1);
        assert!(svc.backlog_stats().transitions >= 4);
    }

    #[test]
    fn metrics_drain_reports_gateway_episodes() {
        let svc = service(2, 8);
        for _ in 0..3 {
            let s = svc.connect().unwrap();
            s.request_action(&[0.1; 4]).unwrap();
            s.log_reward(2.0).unwrap();
            s.end(None).unwrap();
        }
        let (episodes, steps) = svc.set().collect_metrics();
        assert_eq!(episodes.len(), 3);
        assert_eq!(steps, 3);
        assert!(episodes.iter().all(|e| (e.reward - 2.0).abs() < 1e-6));
    }

    #[test]
    fn scale_up_spreads_new_connects() {
        let svc = service(1, 64);
        assert_eq!(svc.num_live_shards(), 1);
        svc.scale_to(3).unwrap();
        assert_eq!(svc.num_live_shards(), 3);
        let shards: std::collections::BTreeSet<usize> = (0..6)
            .map(|_| {
                let s = svc.connect().unwrap();
                let idx = s.shard_idx();
                s.end(None).unwrap();
                idx
            })
            .collect();
        assert!(shards.len() >= 2, "grown shards must receive connects");
    }
}
