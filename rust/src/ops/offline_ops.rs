//! Offline dataflow operators: the log-backed experience source and
//! off-policy evaluation.
//!
//! `read_from_logs` is the offline twin of `store_to_replay_buffer`'s
//! producer side: it tail-follows episode-log streams
//! ([`crate::offline::LogStreamReader`]) and routes every decoded frame
//! into the sharded [`ReplayService`], so an offline plan's replay →
//! learn stage is *identical* to the online one — the only difference
//! is which source op feeds the buffer.  `ope_estimate` consumes the
//! same frames directly and scores a target policy against the logged
//! behavior policy by importance sampling.

use std::path::Path;
use std::time::Duration;

use super::replay_ops::{store_to_replay_buffer, ReplayService};
use crate::iter::LocalIter;
use crate::offline::{discover_streams, LogStreamReader, OfflineCounters};
use crate::util::Backoff;
use crate::SampleBatch;

/// Idle-poll backoff for the log source (same shape as the replay and
/// gateway sources: spin fast while frames flow, back off to a bounded
/// sleep when fully caught up with the writers).
pub const DEFAULT_LOG_BACKOFF_BASE: Duration = Duration::from_micros(200);
pub const DEFAULT_LOG_BACKOFF_CAP: Duration = Duration::from_millis(20);

/// A dataflow source that tail-follows `readers` round-robin and stores
/// every decoded frame into the replay service (pass-through, exactly
/// like `store_to_replay_buffer`).  Yields `Some(batch)` per frame and
/// `None` on idle cycles — it never blocks and never ends, so it
/// composes under `union`/`concurrently` with the replay→learn stage
/// surfaced.
pub fn read_from_logs(
    readers: Vec<LogStreamReader>,
    service: &ReplayService,
) -> LocalIter<Option<SampleBatch>> {
    read_from_logs_with_backoff(
        readers,
        service,
        DEFAULT_LOG_BACKOFF_BASE,
        DEFAULT_LOG_BACKOFF_CAP,
    )
}

/// [`read_from_logs`] with an explicit idle backoff.
pub fn read_from_logs_with_backoff(
    mut readers: Vec<LogStreamReader>,
    service: &ReplayService,
    backoff_base: Duration,
    backoff_cap: Duration,
) -> LocalIter<Option<SampleBatch>> {
    let mut store = store_to_replay_buffer(service);
    let mut backoff = Backoff::new(backoff_base, backoff_cap);
    let mut next_idx = 0usize;
    LocalIter::from_fn(move || {
        if readers.is_empty() {
            std::thread::sleep(backoff.next_delay());
            return Some(None);
        }
        // One round-robin sweep starting after the last productive
        // reader, so a chatty stream cannot starve the others.
        for probe in 0..readers.len() {
            let i = (next_idx + probe) % readers.len();
            if let Some(batch) = readers[i].poll() {
                next_idx = i + 1;
                backoff.reset();
                return Some(Some(store(batch)));
            }
        }
        std::thread::sleep(backoff.next_delay());
        Some(None)
    })
}

/// A *finite* frame stream over the logs currently in `dir`: every
/// stream is discovered and drained until all readers report idle, then
/// the iterator ends.  This is the input shape `ope_estimate` wants —
/// evaluation runs over a static recorded dataset, not a live tail.
pub fn log_frames(dir: impl AsRef<Path>) -> LocalIter<SampleBatch> {
    let dir = dir.as_ref().to_path_buf();
    let counters = OfflineCounters::new();
    let mut readers: Vec<LogStreamReader> = discover_streams(&dir)
        .into_iter()
        .map(|s| LogStreamReader::follow(&dir, s, counters.clone()))
        .collect();
    let mut next_idx = 0usize;
    LocalIter::from_fn(move || {
        for probe in 0..readers.len() {
            let i = (next_idx + probe) % readers.len();
            if let Some(batch) = readers[i].poll() {
                next_idx = i + 1;
                return Some(batch);
            }
        }
        None // every stream idle: static logs are exhausted
    })
}

/// Off-policy evaluation result: importance-sampling estimates of the
/// *target* policy's per-episode return from trajectories collected by
/// the logged *behavior* policy.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpeReport {
    /// Complete episodes scored.
    pub episodes: usize,
    /// Transitions inside those episodes.
    pub steps: usize,
    /// Transitions dropped: trailing partial episodes (no terminal
    /// `done` in the logs) and rows without a recorded behavior logp.
    pub dropped_steps: usize,
    /// Mean logged (behavior-policy) episode return — the baseline the
    /// IS estimators correct.
    pub behavior_mean_return: f64,
    /// Ordinary importance sampling: `mean(w_i · G_i)` — unbiased,
    /// high variance (weights clamped at `exp(±50)` against overflow).
    pub ordinary_is: f64,
    /// Weighted importance sampling: `Σ w_i G_i / Σ w_i` — biased,
    /// much lower variance; the default ranking estimator.
    pub weighted_is: f64,
}

/// Score a target policy on logged trajectories without an env.
///
/// `target_logp(obs_row, action)` returns the target policy's
/// log-probability of the logged action; the behavior logp comes from
/// the `action_logp` column the log writer recorded.  Per-episode
/// importance weights are accumulated in log space
/// (`Σ_t target_logp − behavior_logp`) and applied to the discounted
/// logged return `G = Σ_t γ^t r_t`.
///
/// Episode boundaries are the `done` flags in the stream, which assumes
/// frames arrive in collection order per stream — true for logs written
/// by a single-env worker or gateway session stream.  Trailing steps
/// with no terminal flag, and rows missing a behavior logp, are dropped
/// and counted rather than silently skewing the estimate.
pub fn ope_estimate(
    mut frames: LocalIter<SampleBatch>,
    mut target_logp: impl FnMut(&[f32], i32) -> f64,
    gamma: f64,
) -> OpeReport {
    let mut report = OpeReport::default();
    // Per-episode accumulators (bounded state, episode at a time).
    let mut ep_logw = 0.0f64;
    let mut ep_return = 0.0f64;
    let mut ep_steps = 0usize;
    let mut discount = 1.0f64;
    // Completed episodes: (log importance weight, discounted return).
    let mut episodes: Vec<(f64, f64)> = Vec::new();
    while let Some(batch) = frames.next() {
        let has_logp = batch.action_logp.len() == batch.len();
        for i in 0..batch.len() {
            if !has_logp {
                report.dropped_steps += 1;
                continue;
            }
            let behavior = f64::from(batch.action_logp[i]);
            let target = target_logp(batch.obs_row(i), batch.actions[i]);
            ep_logw += target - behavior;
            ep_return += discount * f64::from(batch.rewards[i]);
            discount *= gamma;
            ep_steps += 1;
            if batch.dones[i] != 0.0 {
                episodes.push((ep_logw, ep_return));
                report.steps += ep_steps;
                ep_logw = 0.0;
                ep_return = 0.0;
                ep_steps = 0;
                discount = 1.0;
            }
        }
    }
    report.dropped_steps += ep_steps; // trailing partial episode
    report.episodes = episodes.len();
    if episodes.is_empty() {
        return report;
    }
    let n = episodes.len() as f64;
    report.behavior_mean_return =
        episodes.iter().map(|&(_, g)| g).sum::<f64>() / n;
    // Ordinary IS, clamped against exp overflow on long episodes.
    report.ordinary_is = episodes
        .iter()
        .map(|&(logw, g)| logw.clamp(-50.0, 50.0).exp() * g)
        .sum::<f64>()
        / n;
    // Weighted IS: shift by the max log-weight so the normalizer is
    // computed at a representable scale.
    let max_logw = episodes
        .iter()
        .map(|&(logw, _)| logw)
        .fold(f64::NEG_INFINITY, f64::max);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for &(logw, g) in &episodes {
        let w = (logw - max_logw).exp();
        num += w * g;
        den += w;
    }
    report.weighted_is = num / den;
    report
}

#[cfg(test)]
mod tests {
    use super::super::replay_ops::create_replay_shards;
    use super::*;
    use crate::offline::{EpisodeLogWriter, WriterConfig};
    use crate::sample_batch::SampleBatchBuilder;
    use std::path::PathBuf;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("flowrl_offops_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// One episode of `n` steps with constant reward and logp.
    fn episode(n: usize, reward: f32, logp: f32) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(1);
        for i in 0..n {
            b.add_transition_with_logp(
                &[i as f32],
                (i % 2) as i32,
                reward,
                &[i as f32 + 1.0],
                i + 1 == n,
                logp,
            );
        }
        b.build()
    }

    #[test]
    fn read_from_logs_feeds_replay_service() {
        let dir = tmp_dir("feeds");
        let mut w =
            EpisodeLogWriter::create(&dir, "s", WriterConfig::default()).unwrap();
        for _ in 0..4 {
            w.append(&episode(8, 1.0, -0.69)).unwrap();
        }
        let counters = OfflineCounters::new();
        let reader = LogStreamReader::follow(&dir, "s", counters.clone());
        let service = create_replay_shards(2, 1, 128, 4, 8);
        let mut source = read_from_logs(vec![reader], &service);
        let mut frames = 0;
        for _ in 0..16 {
            if let Some(Some(_)) = source.next() {
                frames += 1;
            }
        }
        assert_eq!(frames, 4);
        assert_eq!(service.backlog_stats().added, 32);
        assert_eq!(counters.snapshot().transitions, 32);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn log_frames_is_finite_over_static_logs() {
        let dir = tmp_dir("finite");
        for stream in ["a", "b"] {
            let mut w =
                EpisodeLogWriter::create(&dir, stream, WriterConfig::default())
                    .unwrap();
            w.append(&episode(3, 1.0, -0.1)).unwrap();
            w.append(&episode(3, 2.0, -0.1)).unwrap();
        }
        let got = log_frames(&dir).collect();
        assert_eq!(got.len(), 4);
        assert!(log_frames(tmp_dir("empty")).next().is_none());
    }

    #[test]
    fn ope_identical_policies_recover_behavior_return() {
        // target == behavior → all weights 1 → OIS = WIS = mean return.
        let frames = LocalIter::from_items(vec![
            episode(5, 1.0, -0.5),
            episode(10, 1.0, -0.5),
        ]);
        let report = ope_estimate(frames, |_, _| -0.5, 1.0);
        assert_eq!(report.episodes, 2);
        assert_eq!(report.steps, 15);
        assert_eq!(report.dropped_steps, 0);
        assert!((report.behavior_mean_return - 7.5).abs() < 1e-9);
        assert!((report.ordinary_is - 7.5).abs() < 1e-9);
        assert!((report.weighted_is - 7.5).abs() < 1e-9);
    }

    #[test]
    fn ope_upweights_episodes_the_target_prefers() {
        // Short low-return episode vs long high-return episode; a
        // target that assigns higher likelihood to the long episode's
        // actions must estimate above the behavior mean.
        let mut frames = vec![episode(2, 0.0, -0.7)];
        frames.push(episode(8, 1.0, -0.7));
        let report = ope_estimate(
            LocalIter::from_items(frames),
            // Target "recognizes" the high-reward episode by its obs
            // range (longer episode reaches obs >= 2).
            |obs, _| if obs[0] >= 2.0 { -0.1 } else { -1.5 },
            1.0,
        );
        assert!(
            report.weighted_is > report.behavior_mean_return,
            "WIS {} should exceed behavior mean {}",
            report.weighted_is,
            report.behavior_mean_return
        );
        assert!(report.ordinary_is > report.behavior_mean_return);
    }

    #[test]
    fn ope_discounts_and_drops_partials() {
        // One complete 2-step episode (γ=0.5: G = 1 + 0.5·1 = 1.5) and
        // one trailing partial (never done) that must be dropped.
        let mut partial = SampleBatchBuilder::new(1);
        partial.add_transition_with_logp(&[0.0], 0, 99.0, &[1.0], false, -0.5);
        let frames =
            LocalIter::from_items(vec![episode(2, 1.0, -0.5), partial.build()]);
        let report = ope_estimate(frames, |_, _| -0.5, 0.5);
        assert_eq!(report.episodes, 1);
        assert_eq!(report.steps, 2);
        assert_eq!(report.dropped_steps, 1);
        assert!((report.weighted_is - 1.5).abs() < 1e-9);
    }

    #[test]
    fn ope_counts_rows_without_behavior_logp() {
        // add_transition (no logp column) → every row dropped.
        let mut b = SampleBatchBuilder::new(1);
        b.add_transition(&[0.0], 0, 1.0, &[1.0], true);
        let report =
            ope_estimate(LocalIter::from_items(vec![b.build()]), |_, _| 0.0, 1.0);
        assert_eq!(report.episodes, 0);
        assert_eq!(report.dropped_steps, 1);
    }
}
