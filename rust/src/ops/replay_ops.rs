//! Replay-side operators: replay actors, `StoreToReplayBuffer`,
//! `Replay` (paper Fig. 10).

use std::time::Duration;

use crate::actor::{spawn_group, ActorHandle};
use crate::iter::{LocalIter, ParIter};
use crate::replay::{ReplayActorState, ReplaySample};
use crate::sample_batch::SampleBatch;
use crate::util::{Backoff, Rng};

/// First not-ready backoff of [`replay`] (doubles per consecutive
/// not-ready poll, resetting on the first real sample).
pub const DEFAULT_REPLAY_BACKOFF_BASE: Duration = Duration::from_micros(100);

/// Cap on [`replay`]'s not-ready backoff: long warmups poll at this
/// cadence instead of hammering the replay actors' mailboxes, while the
/// first polls after a drain stay sub-millisecond.
pub const DEFAULT_REPLAY_BACKOFF_CAP: Duration = Duration::from_millis(10);

/// The replay actor type (paper: `create_colocated(ReplayActor)`).
pub type ReplayActor = ActorHandle<ReplayActorState>;

/// Spawn `n` replay-buffer actors with ring columns preallocated for
/// `obs_dim`-wide observation rows.
pub fn create_replay_actors(
    n: usize,
    obs_dim: usize,
    capacity: usize,
    learning_starts: usize,
    replay_batch_size: usize,
) -> Vec<ReplayActor> {
    spawn_group("replay", n, move |i| {
        Box::new(move || {
            ReplayActorState::new(
                capacity,
                obs_dim,
                learning_starts,
                replay_batch_size,
                0xC0FFEE + i as u64,
            )
        })
    })
}

/// `StoreToReplayBuffer(actors)`: ship each incoming batch to a
/// randomly chosen replay actor (fire-and-forget, like Ape-X's
/// `random.choice(replay_actors).add_batch.remote(batch)`), passing the
/// batch through for downstream ops (weight updates etc.).  The clone
/// handed to the actor shares the batch's column storage (reference
/// count bump, not a deep copy).
pub fn store_to_replay_buffer(
    actors: Vec<ReplayActor>,
) -> impl FnMut(SampleBatch) -> SampleBatch + Send + 'static {
    let mut rng = Rng::new(0x5703E);
    move |batch| {
        let target = &actors[rng.below(actors.len())];
        let clone = batch.clone();
        target.cast(move |ra| ra.add_batch(&clone));
        batch
    }
}

/// `Replay(actors, num_async)`: an endless stream of prioritized
/// samples drawn from the replay actors, paired with the producing
/// actor's handle (for priority updates).
///
/// Before `learning_starts` the buffers are not ready: the stream
/// yields `None` items (after an exponential backoff, base
/// [`DEFAULT_REPLAY_BACKOFF_BASE`] capped at
/// [`DEFAULT_REPLAY_BACKOFF_CAP`]) instead of blocking — critical under
/// a round-robin `Concurrently`, where a blocking replay child would
/// starve the very store child that must fill the buffer (classic
/// composition deadlock; regression-tested in rust/tests/
/// integration.rs).  Use [`replay_with_backoff`] to tune the cadence.
pub fn replay(
    actors: Vec<ReplayActor>,
    num_async: usize,
) -> LocalIter<Option<(ReplaySample, ReplayActor)>> {
    replay_with_backoff(
        actors,
        num_async,
        DEFAULT_REPLAY_BACKOFF_BASE,
        DEFAULT_REPLAY_BACKOFF_CAP,
    )
}

/// [`replay`] with a configurable not-ready backoff: consecutive
/// not-ready polls sleep `base`, `2*base`, `4*base`, ... capped at
/// `cap`; the first real sample resets the ladder.  A fixed short sleep
/// burns a driver core polling an empty buffer through a long warmup; a
/// fixed long one adds latency to the first samples after a drain —
/// the ladder gives both ends.
pub fn replay_with_backoff(
    actors: Vec<ReplayActor>,
    num_async: usize,
    base: Duration,
    cap: Duration,
) -> LocalIter<Option<(ReplaySample, ReplayActor)>> {
    let mut backoff = Backoff::new(base, cap);
    ParIter::from_actors(actors, |ra: &mut ReplayActorState| Some(ra.replay()))
        .gather_async_with_source(num_async)
        .for_each(move |(maybe, actor)| match maybe {
            Some(s) => {
                backoff.reset();
                Some((s, actor))
            }
            None => {
                // Empty buffer: back off (exponentially, capped) so we
                // don't spin the replay actor's mailbox, then report
                // not-ready.
                std::thread::sleep(backoff.next_delay());
                None
            }
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn transitions(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_transition(
                &[i as f32, 0.0],
                0,
                1.0,
                &[i as f32 + 1.0, 0.0],
                false,
            );
        }
        b.build()
    }

    #[test]
    fn store_op_distributes_to_actors() {
        let actors = create_replay_actors(2, 2, 64, 0, 4);
        let mut op = store_to_replay_buffer(actors.clone());
        for _ in 0..10 {
            let out = op(transitions(4));
            assert_eq!(out.len(), 4); // pass-through
        }
        let totals: Vec<usize> =
            actors.iter().map(|a| a.call(|ra| ra.num_added).unwrap()).collect();
        assert_eq!(totals.iter().sum::<usize>(), 40);
        assert!(totals.iter().all(|&t| t > 0), "both actors used: {totals:?}");
    }

    #[test]
    fn replay_stream_yields_after_learning_starts() {
        let actors = create_replay_actors(2, 2, 64, 8, 4);
        let mut store = store_to_replay_buffer(actors.clone());
        // Feed both actors past learning_starts.
        for _ in 0..8 {
            store(transitions(4));
        }
        let mut it = replay(actors, 2);
        let mut n = 0;
        while n < 5 {
            let Some((sample, actor)) = it.next().unwrap() else {
                continue; // store casts may still be in flight
            };
            assert_eq!(sample.batch.len(), 4);
            assert_eq!(sample.indices.len(), 4);
            // The handle can message the producing actor.
            actor.cast(|ra| ra.num_sampled += 0);
            n += 1;
        }
    }

    #[test]
    fn replay_before_learning_starts_yields_not_ready() {
        let actors = create_replay_actors(1, 2, 64, 1000, 4);
        let mut it = replay(actors, 1);
        // Stream must not block: it reports not-ready instead.
        for _ in 0..3 {
            assert!(it.next().unwrap().is_none());
        }
    }

    #[test]
    fn replay_backoff_grows_while_not_ready() {
        let actors = create_replay_actors(1, 2, 64, 1000, 4);
        let mut it = replay_with_backoff(
            actors,
            1,
            Duration::from_millis(2),
            Duration::from_millis(8),
        );
        let start = std::time::Instant::now();
        for _ in 0..3 {
            assert!(it.next().unwrap().is_none());
        }
        // The ladder slept at least 2 + 4 + 8 ms across the three
        // not-ready polls (a fixed 500us sleep would pass ~1.5ms).
        assert!(
            start.elapsed() >= Duration::from_millis(14),
            "backoff ladder did not grow: {:?}",
            start.elapsed()
        );
    }

    #[test]
    fn priority_update_roundtrip_through_actor() {
        let actors = create_replay_actors(1, 2, 64, 0, 4);
        actors[0]
            .call({
                let batch = transitions(4);
                move |ra| ra.add_batch(&batch)
            })
            .unwrap();
        let (sample, actor) = replay(actors, 1).next().unwrap().unwrap();
        let indices = sample.indices.clone();
        let tds = vec![9.0; indices.len()];
        actor.call(move |ra| ra.update_priorities(&indices, &tds)).unwrap();
        // Priorities applied: the buffer can still sample.
        assert!(actor.call(|ra| ra.replay()).unwrap().is_some());
    }
}
