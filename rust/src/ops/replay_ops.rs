//! Replay-side operators: the elastic replay-shard service,
//! `StoreToReplayBuffer`, `Replay` (paper Fig. 10).
//!
//! Replay is a first-class elastic service here, not a fixed actor
//! list: shards live in a [`ShardRegistry`] behind a
//! [`WorkerSet`](crate::rollout::WorkerSet) exactly like rollout
//! workers, so the same machinery that grows/retires/restarts samplers
//! mid-plan applies to the replay tier — [`store_to_replay_buffer`]
//! routes over the live slot set, [`replay`] gathers through the
//! registry (new shards are adopted by running streams; a replaced
//! incarnation's in-flight samples are discarded by epoch), and
//! priority updates travel through a [`ReplayLease`] that re-resolves
//! the slot and drops updates addressed to a dead incarnation.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::actor::{spawn_group, ActorHandle, ShardRegistry};
use crate::iter::{LocalIter, ParIter};
use crate::replay::{
    ReplayActorState, ReplayBacklogStats, ReplaySample, ReplayShardGauge,
};
use crate::rollout::{RestartPolicy, RestartReport, WorkerSet};
use crate::sample_batch::SampleBatch;
use crate::util::Backoff;

/// First not-ready backoff of [`replay`] (doubles per consecutive
/// not-ready poll, resetting on the first real sample).
pub const DEFAULT_REPLAY_BACKOFF_BASE: Duration = Duration::from_micros(100);

/// Cap on [`replay`]'s not-ready backoff: long warmups poll at this
/// cadence instead of hammering the replay shards' mailboxes, while the
/// first polls after a drain stay sub-millisecond.
pub const DEFAULT_REPLAY_BACKOFF_CAP: Duration = Duration::from_millis(10);

/// The replay actor type (paper: `create_colocated(ReplayActor)`).
pub type ReplayActor = ActorHandle<ReplayActorState>;

/// Seed base for replay shards, kept stable across incarnations of a
/// slot so a restarted shard samples reproducibly.
const REPLAY_SEED_BASE: u64 = 0xC0FFEE;

/// Spawn `n` standalone replay-buffer actors (a plain `Vec`, no
/// registry).  This is the **non-elastic** substrate the low-level
/// baseline twin (`baseline::AsyncReplayOptimizer`, the paper's Listing
/// A4) programs against; the dataflow operators use
/// [`create_replay_shards`] instead.
pub fn create_replay_actors(
    n: usize,
    obs_dim: usize,
    capacity: usize,
    learning_starts: usize,
    replay_batch_size: usize,
) -> Vec<ReplayActor> {
    spawn_group("replay", n, move |i| {
        Box::new(move || {
            ReplayActorState::new(
                capacity,
                obs_dim,
                learning_starts,
                replay_batch_size,
                REPLAY_SEED_BASE + i as u64,
            )
        })
    })
}

/// Lifetime traffic counters of one [`ReplayService`], shared by its
/// store/replay operators and leases.  Service-scoped (not per shard):
/// they survive shard restarts and retires, so the backlog telemetry's
/// rates stay monotone under churn.
#[derive(Debug, Default)]
pub struct ReplayCounters {
    /// Batches routed to a shard by [`store_to_replay_buffer`].
    pub stores: AtomicU64,
    /// Samples yielded by the [`replay`] stream.
    pub samples: AtomicU64,
    /// Not-ready polls (shard below its learning-starts threshold).
    pub not_ready: AtomicU64,
    /// Priority updates applied to the producing incarnation.
    pub priority_applied: AtomicU64,
    /// Priority updates discarded: the producing incarnation was
    /// restarted (epoch moved) or its slot retired before the learner's
    /// TD errors came back.
    pub priority_discarded: AtomicU64,
}

/// The elastic replay tier: prioritized replay shards in a
/// [`ShardRegistry`]-backed [`WorkerSet`], plus shared traffic counters
/// and per-slot backlog gauges.
///
/// * **Sharding** — [`store_to_replay_buffer`] hashes each incoming
///   batch's arrival id over the live slot set; shards added by
///   [`ReplayService::scale_to`] start receiving their share on the
///   next batch, retired slots drop out of rotation.
/// * **Epochs** — a shard restarted by
///   [`ReplayService::restart_dead_with_policy`] is published under a
///   bumped registry epoch.  In-flight samples of the dead incarnation
///   are discarded by the gather's epoch machinery, and priority
///   updates still referencing it are dropped by the [`ReplayLease`]
///   (buffer slot indices are meaningless across incarnations).
/// * **Recovery semantics** — the sync protocol is a no-op: a restarted
///   shard rejoins *empty*.  Replay contents are lost on a crash by
///   design (they are re-derivable experience, not model state), which
///   is also what keeps restart cheap and double-count-free.
///
/// Cloning shares all state (the underlying `WorkerSet` handle
/// semantics), so plan closures and reporting operators can hold the
/// service cheaply.
#[derive(Clone)]
pub struct ReplayService {
    set: WorkerSet<ReplayActorState>,
    counters: Arc<ReplayCounters>,
    /// Per-slot backlog gauges, index-aligned with the registry.  The
    /// factory re-attaches slot `i`'s gauge to every incarnation
    /// spawned into `i`, so a reading always describes the current one.
    gauges: Arc<Mutex<Vec<Arc<ReplayShardGauge>>>>,
}

impl ReplayService {
    /// Spawn `num_shards` replay shards (named `replay-{i}`, seeded
    /// `0xC0FFEE + i`) behind a fresh registry.  The set's local slot
    /// is a 1-transition sentinel that never sees traffic — store
    /// routes and replay gathers touch only the remote shards.
    pub fn new(
        num_shards: usize,
        obs_dim: usize,
        capacity: usize,
        learning_starts: usize,
        replay_batch_size: usize,
    ) -> Self {
        assert!(num_shards >= 1, "replay service needs at least one shard");
        let gauges: Arc<Mutex<Vec<Arc<ReplayShardGauge>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let factory_gauges = gauges.clone();
        let set = WorkerSet::with_protocol(
            "replay-local",
            "replay",
            num_shards,
            move |i| {
                if i == 0 {
                    // Local sentinel: with_protocol's learner slot.  It
                    // only serves liveness probes for spawn_synced.
                    return Box::new(move || {
                        ReplayActorState::new(
                            1,
                            obs_dim,
                            usize::MAX,
                            replay_batch_size,
                            REPLAY_SEED_BASE,
                        )
                    });
                }
                let slot = i - 1;
                let gauge = {
                    let mut g = factory_gauges.lock().unwrap();
                    while g.len() <= slot {
                        g.push(Arc::new(ReplayShardGauge::default()));
                    }
                    g[slot].clone()
                };
                Box::new(move || {
                    ReplayActorState::new(
                        capacity,
                        obs_dim,
                        learning_starts,
                        replay_batch_size,
                        REPLAY_SEED_BASE + slot as u64,
                    )
                    .with_gauge(gauge)
                })
            },
            // No sync protocol: replay shards carry no model state and
            // restart empty (see the type-level docs).
            |_local, _fresh| Ok(()),
        );
        ReplayService {
            set,
            counters: Arc::new(ReplayCounters::default()),
            gauges,
        }
    }

    /// The underlying elastic set (registry, scale/fault counters,
    /// restart machinery).
    pub fn set(&self) -> &WorkerSet<ReplayActorState> {
        &self.set
    }

    /// The shard table — gathers built from a clone adopt membership
    /// changes live.
    pub fn registry(&self) -> &ShardRegistry<ReplayActorState> {
        self.set.registry()
    }

    pub fn counters(&self) -> Arc<ReplayCounters> {
        self.counters.clone()
    }

    pub fn num_live_shards(&self) -> usize {
        self.registry().num_live()
    }

    /// Scale the live shard count to exactly `n` under running store +
    /// replay traffic (delegates to `WorkerSet::scale_to`).
    pub fn scale_to(
        &self,
        n: usize,
    ) -> crate::util::error::Result<(Vec<usize>, Vec<usize>)> {
        self.set.scale_to(n)
    }

    /// Respawn crashed shards under a [`RestartPolicy`] (bounded
    /// backoff, circuit breaker).  Replacements rejoin empty under a
    /// new epoch; see the type-level docs for why that is correct.
    pub fn restart_dead_with_policy(
        &self,
        policy: &RestartPolicy,
    ) -> RestartReport {
        self.set.restart_dead_with_policy(policy)
    }

    /// Point-in-time backlog telemetry over the live shards — mailbox
    /// depths from actor telemetry, ring fill from the slot gauges
    /// (lock-free; a blocking `call` would queue the reporter behind
    /// the very backlog being measured), lifetime traffic from the
    /// service counters.  Attached to `TrainResult::replay` and fed to
    /// `Autoscaler::replay_signals`.
    pub fn backlog_stats(&self) -> ReplayBacklogStats {
        let registry = self.registry();
        let gauges = self.gauges.lock().unwrap();
        let mut out = ReplayBacklogStats {
            slots: registry.len(),
            ..Default::default()
        };
        for i in registry.live_indices() {
            let Some((handle, _epoch)) = registry.get_live(i) else {
                continue;
            };
            out.live_shards += 1;
            let s = handle.stats();
            out.max_queue_len = out.max_queue_len.max(s.queue_len);
            out.max_queue_hwm = out.max_queue_hwm.max(s.queue_hwm);
            if let Some(g) = gauges.get(i) {
                out.max_ring_fill = out.max_ring_fill.max(g.ring_fill());
                out.added += g.num_added.load(Relaxed);
                out.sampled += g.num_sampled.load(Relaxed);
            }
        }
        out.stores = self.counters.stores.load(Relaxed);
        out.samples = self.counters.samples.load(Relaxed);
        out.not_ready = self.counters.not_ready.load(Relaxed);
        out.priority_applied = self.counters.priority_applied.load(Relaxed);
        out.priority_discarded =
            self.counters.priority_discarded.load(Relaxed);
        out
    }
}

/// Spawn an elastic replay tier — the dataflow-facing constructor
/// (paper: `create_colocated(ReplayActor)`, upgraded to a registry).
pub fn create_replay_shards(
    num_shards: usize,
    obs_dim: usize,
    capacity: usize,
    learning_starts: usize,
    replay_batch_size: usize,
) -> ReplayService {
    ReplayService::new(
        num_shards,
        obs_dim,
        capacity,
        learning_starts,
        replay_batch_size,
    )
}

/// SplitMix64 — the batch-id hash behind the store op's shard routing.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `StoreToReplayBuffer(service)`: ship each incoming batch to the
/// shard selected by hashing the batch's arrival id over the **live**
/// slot set (fire-and-forget, like Ape-X's
/// `random.choice(replay_actors).add_batch.remote(batch)` but
/// registry-backed), passing the batch through for downstream ops
/// (weight updates etc.).  The clone handed to the shard shares the
/// batch's column storage (reference-count bump, not a deep copy).
///
/// Routing re-reads the registry per batch: shards grown mid-plan join
/// the rotation on the next batch, retired slots leave it, and a
/// restarted slot receives under its new incarnation.  With no live
/// shard (all crashed, none restarted yet) the batch passes through
/// unstored rather than panicking the store subflow.
pub fn store_to_replay_buffer(
    service: &ReplayService,
) -> impl FnMut(SampleBatch) -> SampleBatch + Send + 'static {
    let registry = service.registry().clone();
    let counters = service.counters();
    let mut batch_seq: u64 = 0;
    move |batch| {
        let live = registry.live_indices();
        if !live.is_empty() {
            let slot =
                live[(splitmix64(batch_seq) % live.len() as u64) as usize];
            if let Some((shard, _epoch)) = registry.get_live(slot) {
                let clone = batch.clone();
                shard.cast(move |ra| ra.add_batch(&clone));
                counters.stores.fetch_add(1, Relaxed);
            }
        }
        batch_seq = batch_seq.wrapping_add(1);
        batch
    }
}

/// A lease on the shard incarnation that produced a [`ReplaySample`]:
/// the learner's priority feedback goes back through the registry, not
/// a raw handle, so an update addressed to a dead incarnation —
/// restarted (epoch bumped) or retired since the sample was drawn — is
/// **discarded** instead of poking a fresh buffer whose slot indices
/// mean something else entirely.
#[derive(Clone)]
pub struct ReplayLease {
    registry: ShardRegistry<ReplayActorState>,
    /// `usize::MAX` when the producer had already left the registry at
    /// yield time (its slot retired mid-flight).
    shard_idx: usize,
    epoch: u64,
    /// Actor id of the producing incarnation — belt over the epoch
    /// check (ids are globally unique; epochs are per-slot).
    incarnation: u64,
    counters: Arc<ReplayCounters>,
}

impl ReplayLease {
    fn locate(
        registry: &ShardRegistry<ReplayActorState>,
        shard: &ReplayActor,
        counters: &Arc<ReplayCounters>,
    ) -> Self {
        let mut shard_idx = usize::MAX;
        let mut epoch = 0;
        for i in registry.live_indices() {
            if let Some((h, e)) = registry.get_live(i) {
                if h.id() == shard.id() {
                    shard_idx = i;
                    epoch = e;
                    break;
                }
            }
        }
        ReplayLease {
            registry: registry.clone(),
            shard_idx,
            epoch,
            incarnation: shard.id(),
            counters: counters.clone(),
        }
    }

    /// The producing slot, if it was still live at yield time.
    pub fn shard_idx(&self) -> Option<usize> {
        (self.shard_idx != usize::MAX).then_some(self.shard_idx)
    }

    /// The producing incarnation's registry epoch at yield time.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Send `|TD|`-error priorities back to the producing shard.
    /// Returns `false` (and counts a discard) if the incarnation is
    /// gone — slot retired, or restarted under a newer epoch.
    pub fn update_priorities(
        &self,
        indices: Vec<usize>,
        td_abs: Vec<f32>,
    ) -> bool {
        let live = (self.shard_idx != usize::MAX)
            .then(|| self.registry.get_live(self.shard_idx))
            .flatten();
        match live {
            Some((handle, epoch))
                if epoch == self.epoch
                    && handle.id() == self.incarnation =>
            {
                self.counters.priority_applied.fetch_add(1, Relaxed);
                handle.cast(move |ra| {
                    ra.update_priorities(&indices, &td_abs)
                });
                true
            }
            _ => {
                self.counters.priority_discarded.fetch_add(1, Relaxed);
                false
            }
        }
    }
}

/// `Replay(service, num_async)`: an endless stream of prioritized
/// samples gathered **through the shard registry** — shards grown by
/// `scale_to` are adopted mid-stream, retired/replaced incarnations'
/// in-flight samples are discarded by epoch — each paired with a
/// [`ReplayLease`] for the priority round-trip.
///
/// Before `learning_starts` the shards are not ready: the stream
/// yields `None` items (after an exponential backoff, base
/// [`DEFAULT_REPLAY_BACKOFF_BASE`] capped at
/// [`DEFAULT_REPLAY_BACKOFF_CAP`]) instead of blocking — critical under
/// a round-robin `Concurrently`, where a blocking replay child would
/// starve the very store child that must fill the buffer (classic
/// composition deadlock; regression-tested in rust/tests/
/// integration.rs).  Use [`replay_with_backoff`] to tune the cadence.
pub fn replay(
    service: &ReplayService,
    num_async: usize,
) -> LocalIter<Option<(ReplaySample, ReplayLease)>> {
    replay_with_backoff(
        service,
        num_async,
        DEFAULT_REPLAY_BACKOFF_BASE,
        DEFAULT_REPLAY_BACKOFF_CAP,
    )
}

/// [`replay`] with a configurable not-ready backoff: consecutive
/// not-ready polls sleep `base`, `2*base`, `4*base`, ... capped at
/// `cap`; the first real sample resets the ladder.  A fixed short sleep
/// burns a driver core polling an empty buffer through a long warmup; a
/// fixed long one adds latency to the first samples after a drain —
/// the ladder gives both ends.
pub fn replay_with_backoff(
    service: &ReplayService,
    num_async: usize,
    base: Duration,
    cap: Duration,
) -> LocalIter<Option<(ReplaySample, ReplayLease)>> {
    let registry = service.registry().clone();
    let counters = service.counters();
    let mut backoff = Backoff::new(base, cap);
    ParIter::from_registry(registry.clone(), |ra: &mut ReplayActorState| {
        Some(ra.replay())
    })
    .gather_async_with_source(num_async)
    .for_each(move |(maybe, shard)| match maybe {
        Some(s) => {
            backoff.reset();
            counters.samples.fetch_add(1, Relaxed);
            let lease = ReplayLease::locate(&registry, &shard, &counters);
            Some((s, lease))
        }
        None => {
            // Empty buffer: back off (exponentially, capped) so we
            // don't spin the shard's mailbox, then report not-ready.
            counters.not_ready.fetch_add(1, Relaxed);
            std::thread::sleep(backoff.next_delay());
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sample_batch::SampleBatchBuilder;

    fn transitions(n: usize) -> SampleBatch {
        let mut b = SampleBatchBuilder::new(2);
        for i in 0..n {
            b.add_transition(
                &[i as f32, 0.0],
                0,
                1.0,
                &[i as f32 + 1.0, 0.0],
                false,
            );
        }
        b.build()
    }

    /// Sum of `num_added` over the live shards, via the slot gauges
    /// (waiting out in-flight store casts with a bounded retry).
    fn total_added(service: &ReplayService, expect: usize) -> usize {
        for _ in 0..200 {
            let added = service.backlog_stats().added as usize;
            if added >= expect {
                return added;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        service.backlog_stats().added as usize
    }

    #[test]
    fn store_op_distributes_across_shards() {
        let service = create_replay_shards(2, 2, 64, 0, 4);
        let mut op = store_to_replay_buffer(&service);
        for _ in 0..10 {
            let out = op(transitions(4));
            assert_eq!(out.len(), 4); // pass-through
        }
        assert_eq!(total_added(&service, 40), 40);
        let per_shard: Vec<usize> = service
            .registry()
            .handles()
            .iter()
            .map(|a| a.call(|ra| ra.num_added).unwrap())
            .collect();
        assert!(
            per_shard.iter().all(|&t| t > 0),
            "hash routing must use both shards: {per_shard:?}"
        );
        assert_eq!(service.backlog_stats().stores, 10);
    }

    #[test]
    fn replay_stream_yields_leases_after_learning_starts() {
        let service = create_replay_shards(2, 2, 64, 8, 4);
        let mut store = store_to_replay_buffer(&service);
        // Feed both shards past learning_starts.
        for _ in 0..10 {
            store(transitions(4));
        }
        let mut it = replay(&service, 2);
        let mut n = 0;
        while n < 5 {
            let Some((sample, lease)) = it.next().unwrap() else {
                continue; // store casts may still be in flight
            };
            assert_eq!(sample.batch.len(), 4);
            assert_eq!(sample.indices.len(), 4);
            // The lease resolved the producing slot and its updates
            // reach the live incarnation.
            assert!(lease.shard_idx().is_some());
            let tds = vec![1.0; sample.indices.len()];
            assert!(lease.update_priorities(sample.indices, tds));
            n += 1;
        }
        assert!(service.backlog_stats().priority_applied >= 5);
    }

    #[test]
    fn replay_before_learning_starts_yields_not_ready() {
        let service = create_replay_shards(1, 2, 64, 1000, 4);
        let mut it = replay(&service, 1);
        // Stream must not block: it reports not-ready instead.
        for _ in 0..3 {
            assert!(it.next().unwrap().is_none());
        }
        assert!(service.backlog_stats().not_ready >= 3);
    }

    #[test]
    fn replay_backoff_grows_while_not_ready() {
        let service = create_replay_shards(1, 2, 64, 1000, 4);
        let mut it = replay_with_backoff(
            &service,
            1,
            Duration::from_millis(2),
            Duration::from_millis(8),
        );
        let start = std::time::Instant::now();
        for _ in 0..3 {
            assert!(it.next().unwrap().is_none());
        }
        // The ladder slept at least 2 + 4 + 8 ms across the three
        // not-ready polls (a fixed 500us sleep would pass ~1.5ms).
        assert!(
            start.elapsed() >= Duration::from_millis(14),
            "backoff ladder did not grow: {:?}",
            start.elapsed()
        );
    }

    #[test]
    fn priority_update_to_restarted_shard_is_discarded_by_epoch() {
        let service = create_replay_shards(1, 2, 64, 0, 4);
        let (shard, epoch0) = service.registry().get_live(0).unwrap();
        shard
            .call({
                let batch = transitions(4);
                move |ra| ra.add_batch(&batch)
            })
            .unwrap();
        let (sample, lease) = replay(&service, 1).next().unwrap().unwrap();
        assert_eq!(lease.epoch(), epoch0);

        // Kill the shard and restart it: new incarnation, bumped epoch.
        assert!(shard.call(|_| -> () { panic!("fault injection") }).is_err());
        assert!(shard.await_poisoned(Duration::from_secs(2)));
        assert_eq!(service.set().restart_dead(), vec![0]);
        assert!(service.registry().epoch(0) > epoch0);

        // The lease's priorities reference the dead incarnation's ring
        // slots — they must be dropped, not applied to the fresh one.
        let tds = vec![9.0; sample.indices.len()];
        assert!(!lease.update_priorities(sample.indices, tds));
        let stats = service.backlog_stats();
        assert_eq!(stats.priority_discarded, 1);
        assert_eq!(stats.priority_applied, 0);
    }

    #[test]
    fn priority_update_to_retired_slot_is_discarded() {
        let service = create_replay_shards(2, 2, 64, 0, 4);
        let mut store = store_to_replay_buffer(&service);
        for _ in 0..6 {
            store(transitions(4));
        }
        total_added(&service, 24);
        let (sample, lease) = replay(&service, 1).next().unwrap().unwrap();
        let idx = lease.shard_idx().unwrap();
        // Retire the producing slot under the lease's feet.
        assert!(service.set().remove_worker(idx));
        let tds = vec![9.0; sample.indices.len()];
        assert!(!lease.update_priorities(sample.indices, tds));
        assert_eq!(service.backlog_stats().priority_discarded, 1);
    }

    #[test]
    fn store_routes_around_scale_events() {
        let service = create_replay_shards(2, 2, 64, 0, 4);
        let mut store = store_to_replay_buffer(&service);
        for _ in 0..4 {
            store(transitions(4));
        }
        assert_eq!(total_added(&service, 16), 16);
        // Grow to 3: the new shard joins the rotation on later batches.
        service.scale_to(3).unwrap();
        for _ in 0..12 {
            store(transitions(4));
        }
        assert_eq!(total_added(&service, 64), 64);
        let third = service
            .registry()
            .get_live(2)
            .expect("grown shard live")
            .0
            .call(|ra| ra.num_added)
            .unwrap();
        assert!(third > 0, "grown shard never received a batch");
        // Shrink back to 1: routing must not panic and the survivor
        // takes all subsequent batches.
        service.scale_to(1).unwrap();
        let before = service.backlog_stats().added;
        for _ in 0..4 {
            store(transitions(4));
        }
        assert_eq!(
            total_added(&service, before as usize + 16) as u64,
            before + 16
        );
    }

    #[test]
    fn backlog_stats_see_queue_and_fill() {
        let service = create_replay_shards(1, 2, 32, 0, 4);
        let mut store = store_to_replay_buffer(&service);
        for _ in 0..8 {
            store(transitions(4));
        }
        total_added(&service, 32);
        let stats = service.backlog_stats();
        assert_eq!(stats.live_shards, 1);
        assert_eq!(stats.slots, 1);
        assert!(
            (stats.max_ring_fill - 1.0).abs() < 1e-12,
            "32 adds into a 32-ring: fill={}",
            stats.max_ring_fill
        );
        assert_eq!(stats.added, 32);
        assert_eq!(stats.stores, 8);
    }
}
