//! Rollout-side operators: `ParallelRollouts`, `ConcatBatches`,
//! `SelectExperiences`.

use crate::actor::ActorHandle;
use crate::iter::ParIter;
use crate::rollout::{MultiAgentRolloutWorker, RolloutWorker, WorkerSet};
use crate::sample_batch::{MultiAgentBatch, SampleBatch};

/// `ParallelRollouts(workers)`: a parallel stream of experience batches,
/// one shard per rollout worker (paper Fig. 5).  Gather with
/// `.gather_async(n)` (A3C/Ape-X/IMPALA) or `.gather_sync()` +
/// `concat` (A2C/PPO's bulk-sync mode).  The handles are captured at
/// build time; prefer [`parallel_rollouts_from`] over a `WorkerSet` so
/// restarted workers rejoin the running gather.
pub fn parallel_rollouts(
    workers: Vec<ActorHandle<RolloutWorker>>,
) -> ParIter<RolloutWorker, SampleBatch> {
    ParIter::from_actors(workers, |w| Some(w.sample()))
}

/// [`parallel_rollouts`] over a `WorkerSet`'s **shard registry**: every
/// dispatch resolves worker index -> handle through the set, so a
/// worker replaced by `WorkerSet::restart_dead` joins the *running*
/// stream on its next dispatch — no plan rebuild (ROADMAP "dynamic
/// plan re-binding").
pub fn parallel_rollouts_from(
    workers: &WorkerSet,
) -> ParIter<RolloutWorker, SampleBatch> {
    ParIter::from_registry(workers.registry().clone(), |w| Some(w.sample()))
}

/// [`parallel_rollouts_from`] for a multi-agent `WorkerSet`: a parallel
/// stream of [`MultiAgentBatch`]es over the set's shard registry, so
/// multi-agent plans ride the same elastic machinery (restart rejoin,
/// `scale_to`, autoscaling) as the single-agent path.
pub fn parallel_ma_rollouts_from(
    workers: &WorkerSet<MultiAgentRolloutWorker>,
) -> ParIter<MultiAgentRolloutWorker, MultiAgentBatch> {
    ParIter::from_registry(workers.registry().clone(), |w| Some(w.sample()))
}

/// `ConcatBatches(min_batch_size)`: buffer incoming batches until the
/// target step count, then emit one concatenated train batch.  Hand to
/// `LocalIter::combine`.
pub fn concat_batches(
    min_batch_size: usize,
) -> impl FnMut(SampleBatch) -> Vec<SampleBatch> + Send + 'static {
    let mut pending: Vec<SampleBatch> = Vec::new();
    let mut count = 0usize;
    move |batch| {
        count += batch.len();
        pending.push(batch);
        if count >= min_batch_size {
            count = 0;
            vec![SampleBatch::concat_all(&std::mem::take(&mut pending))]
        } else {
            vec![]
        }
    }
}

/// Like [`concat_batches`] but emits batches of *exactly* `size` rows,
/// carrying any remainder into the next emission.  Static-shape HLO
/// artifacts want exact row counts; this keeps every collected step
/// (instead of pad_or_truncate silently dropping the surplus).
pub fn exact_batches(
    size: usize,
) -> impl FnMut(SampleBatch) -> Vec<SampleBatch> + Send + 'static {
    assert!(size > 0);
    let mut pending: Option<SampleBatch> = None;
    move |batch| {
        let merged = match pending.take() {
            Some(p) => SampleBatch::concat_all(&[p, batch]),
            None => batch,
        };
        let mut out = Vec::new();
        let mut start = 0;
        while merged.len() - start >= size {
            out.push(merged.slice(start, start + size));
            start += size;
        }
        if start < merged.len() {
            pending = Some(merged.slice(start, merged.len()));
        }
        out
    }
}

/// `SelectExperiences(policy_id)`: extract one policy's sub-batch from a
/// multi-agent batch (paper Fig. 12, `Select(policy="PPO")`).  Empty
/// sub-batches are dropped (hand to `filter_map`).
pub fn select_policy(
    policy_id: &str,
) -> impl FnMut(MultiAgentBatch) -> Option<SampleBatch> + Send + 'static {
    let pid = policy_id.to_string();
    move |ma| ma.select(&pid).filter(|b| !b.is_empty()).cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{DummyEnv, Env};
    use crate::policy::DummyPolicy;
    use crate::rollout::CollectMode;

    fn worker_group(n: usize, fragment: usize) -> Vec<ActorHandle<RolloutWorker>> {
        crate::actor::spawn_group("w", n, move |_| {
            Box::new(move || {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    fragment,
                    CollectMode::OnPolicy,
                )
            })
        })
    }

    #[test]
    fn parallel_rollouts_bulk_sync_round() {
        let mut it = parallel_rollouts(worker_group(3, 8)).gather_sync();
        let round = it.next().unwrap();
        assert_eq!(round.len(), 3);
        assert!(round.iter().all(|b| b.len() == 8));
    }

    #[test]
    fn concat_batches_reaches_target() {
        let mut op = concat_batches(20);
        let mk = |n: usize| {
            let mut b = SampleBatch::new(1);
            b.obs = vec![0.0; n].into();
            b.actions = vec![0; n].into();
            b
        };
        assert!(op(mk(8)).is_empty());
        assert!(op(mk(8)).is_empty());
        let out = op(mk(8));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 24);
        // Buffer reset after emission.
        assert!(op(mk(8)).is_empty());
    }

    #[test]
    fn exact_batches_chunks_and_carries_remainder() {
        let mut op = exact_batches(10);
        let mk = |n: usize| {
            let mut b = SampleBatch::new(1);
            b.obs = (0..n).map(|i| i as f32).collect();
            b.actions = vec![0; n].into();
            b
        };
        assert!(op(mk(6)).is_empty());
        let out = op(mk(7)); // 13 rows total -> one 10-row batch, 3 left
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 10);
        let out2 = op(mk(27)); // 30 rows -> three 10-row batches
        assert_eq!(out2.len(), 3);
        assert!(out2.iter().all(|b| b.len() == 10));
        // No rows lost or duplicated: obs values are per-input indices;
        // total emitted = 40 rows from 40 fed.
        let emitted: usize =
            out.iter().chain(out2.iter()).map(|b| b.len()).sum();
        assert_eq!(emitted, 40);
    }

    #[test]
    fn select_policy_filters_and_extracts() {
        let mut op = select_policy("ppo");
        let mut b = SampleBatch::new(1);
        b.obs = vec![0.0; 3].into();
        b.actions = vec![0; 3].into();
        let ma = MultiAgentBatch::from_single("ppo", b);
        assert_eq!(op(ma).unwrap().len(), 3);
        let other = MultiAgentBatch::from_single("dqn", SampleBatch::new(1));
        assert!(op(other).is_none());
    }
}
