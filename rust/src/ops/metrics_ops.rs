//! `StandardMetricsReporting` — the terminal operator every algorithm
//! plan returns: folds training items and worker episode stats into
//! `TrainResult`s (RLlib's train-result dict).

use crate::iter::LocalIter;
use crate::metrics::{MetricsHub, TrainResult};
use crate::rollout::WorkerSet;

use super::TrainItem;

/// Wrap a training stream: each output pulls `items_per_report` train
/// items, drains episode metrics from all workers, and emits a
/// `TrainResult` snapshot.
pub fn standard_metrics_reporting(
    inner: LocalIter<TrainItem>,
    workers: &WorkerSet,
    items_per_report: usize,
) -> LocalIter<TrainResult> {
    assert!(items_per_report >= 1);
    let mut inner = inner;
    let mut hub = MetricsHub::new(100);
    let local = workers.local.clone();
    let remotes = workers.remotes.clone();
    LocalIter::from_fn(move || {
        for _ in 0..items_per_report {
            let item = inner.next()?;
            hub.num_env_steps_trained += item.steps_trained as u64;
            hub.num_grad_updates += 1;
            for (k, v) in item.stats {
                hub.record_learner_stat(&k, v);
            }
        }
        // Drain episodes + sampled counters from every worker.
        let replies: Vec<_> = std::iter::once(&local)
            .chain(remotes.iter())
            .map(|h| {
                h.call_deferred(|w| {
                    let eps = w.pop_episodes();
                    let steps = w.num_steps_sampled;
                    w.num_steps_sampled = 0;
                    (eps, steps)
                })
            })
            .collect();
        for r in replies {
            let (eps, steps) = r.recv();
            hub.record_episodes(&eps);
            hub.num_env_steps_sampled += steps as u64;
        }
        Some(hub.snapshot())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{DummyEnv, Env};
    use crate::ops::{parallel_rollouts, train_one_step};
    use crate::policy::DummyPolicy;
    use crate::rollout::{CollectMode, RolloutWorker};

    fn worker_set(n_remote: usize) -> WorkerSet {
        WorkerSet::new(n_remote, |_| {
            Box::new(|| {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    10,
                    CollectMode::OnPolicy,
                )
            })
        })
    }

    #[test]
    fn reports_aggregate_training_and_episodes() {
        let workers = worker_set(2);
        let mut train = train_one_step(
            workers.local.clone(),
            workers.remotes.clone(),
        );
        let train_op = parallel_rollouts(workers.remotes.to_vec())
            .gather_async(1)
            .for_each(move |b| train(b));
        let mut reports =
            standard_metrics_reporting(train_op, &workers, 2).take(3);
        let mut last = None;
        while let Some(r) = reports.next() {
            last = Some(r);
        }
        let r = last.unwrap();
        // 3 reports x 2 items x 10 steps trained.
        assert_eq!(r.num_env_steps_trained, 60);
        assert_eq!(r.num_grad_updates, 6);
        assert!(r.num_env_steps_sampled >= 60);
        assert!(r.episodes_total >= 4); // 10-step episodes on DummyEnv
        assert!(r.learner_stats.contains_key("loss"));
    }
}
