//! `StandardMetricsReporting` — the terminal operator every algorithm
//! plan returns: folds training items and worker episode stats into
//! `TrainResult`s (RLlib's train-result dict), and attaches a snapshot
//! of every live actor's runtime telemetry (queue depth, utilization,
//! supervision state) so each report shows *where* the pipeline is
//! starved, not just how fast it moved.
//!
//! One builder, [`Reporting`], is the single entry point: start from
//! the training stream and the worker set, then opt sections in —
//! [`Reporting::autoscale`] closes the sampler elasticity loop,
//! [`Reporting::replay`] attaches (and optionally autoscales) a replay
//! tier, [`Reporting::gateway`] an external-episode gateway tier,
//! [`Reporting::offline`] a log-ingestion tier.  (The four historical
//! free-function entry points that predated the builder were deprecated
//! in 0.8.0 and are gone.)

use std::sync::Arc;
use std::time::Instant;

use crate::actor::{ActorHandle, Autoscaler};
use crate::iter::LocalIter;
use crate::metrics::{EpisodeRecord, MetricsHub, TrainResult};
use crate::offline::OfflineCounters;
use crate::rollout::{RolloutWorker, WorkerMetrics, WorkerSet};

use super::gateway_ops::GatewayService;
use super::replay_ops::ReplayService;
use super::TrainItem;

/// The shared reporting tail: drain episode/step counters from every
/// worker actor in parallel (a poisoned worker's reply resolves to Err
/// and is skipped — a worker fault must not panic the driver), then
/// snapshot the hub with the actor-telemetry registry attached.  Used
/// by [`Reporting`] (and therefore every worker flavor) so the reports
/// cannot drift.
pub(crate) fn drain_and_snapshot<A: 'static>(
    hub: &mut MetricsHub,
    local: &ActorHandle<A>,
    remotes: &[ActorHandle<A>],
    drain: fn(&mut A) -> (Vec<EpisodeRecord>, usize),
) -> TrainResult {
    let replies: Vec<_> = std::iter::once(local)
        .chain(remotes.iter())
        .map(|h| h.call_deferred(move |w| drain(w)))
        .collect();
    for r in replies {
        if let Ok((eps, steps)) = r.recv() {
            hub.record_episodes(&eps);
            hub.num_env_steps_sampled += steps as u64;
        }
    }
    let mut snap = hub.snapshot();
    snap.actor_stats = crate::actor::all_actor_stats();
    snap
}

/// One controller step against a set — shared by the single- and
/// multi-agent reporting operators so the decide/apply protocol cannot
/// drift: the pool is `handles` (the registry snapshot this report
/// already drained through), the report's snapshot is reduced to
/// interval signals (`snap.weight_casts` feeds the shed gauge when
/// present), the directive is applied with `WorkerSet::scale_to`
/// (failures are counted, never fatal), and the decision counters are
/// attached to the snapshot.
pub(crate) fn drive_autoscaler<W: 'static>(
    a: &mut Autoscaler,
    snap: &mut TrainResult,
    set: &WorkerSet<W>,
    local_id: u64,
    handles: &[ActorHandle<W>],
) {
    let sampler_ids: Vec<u64> = handles.iter().map(|h| h.id()).collect();
    let signals = a.signals(
        &snap.actor_stats,
        local_id,
        &sampler_ids,
        snap.weight_casts,
        set.registry().num_live(),
    );
    if let Some(d) = a.decide(&signals) {
        if set.scale_to(d.target).is_err() {
            a.note_failed();
        }
    }
    snap.autoscale = Some(a.stats());
}

/// The one metrics-reporting entry point: a builder from a training
/// stream + worker set to the terminal `TrainResult` stream, with the
/// optional telemetry/elasticity sections opted in per plan:
///
/// ```ignore
/// Reporting::new(train_op, &workers, 2)
///     .autoscale(sampler_controller)             // sampler pool loop
///     .replay(&replay_service, Some(replay_ctl)) // replay tier
///     .gateway(&gateway_service, Some(gw_ctl))   // gateway tier
///     .build()
/// ```
///
/// Each output pulls `items_per_report` train items, drains episode
/// metrics from all workers (dead workers are skipped, not fatal — a
/// worker restarted by `WorkerSet::restart_dead` mid-training is
/// drained from the first report after the restart, since workers are
/// resolved through the set's shard registry at every report), and
/// emits a `TrainResult` carrying per-actor utilization/queue-depth
/// stats, the set's elastic scale events, fault-supervision counters,
/// and — iff the set has a sole broadcast lane
/// ([`WorkerSet::sole_caster_stats`]) — the weight-cast eviction
/// counters.  Works over any `WorkerSet<W: WorkerMetrics>`: rollout
/// workers, multi-agent workers, and gateway shards all report through
/// the same tail, so dead-worker handling cannot drift between them.
pub struct Reporting<W: 'static = RolloutWorker> {
    inner: LocalIter<TrainItem>,
    workers: WorkerSet<W>,
    items_per_report: usize,
    autoscaler: Option<Autoscaler>,
    replay: Option<(ReplayService, Option<Autoscaler>)>,
    gateway: Option<(GatewayService, Option<Autoscaler>)>,
    offline: Option<Arc<OfflineCounters>>,
}

impl<W: WorkerMetrics + 'static> Reporting<W> {
    pub fn new(
        inner: LocalIter<TrainItem>,
        workers: &WorkerSet<W>,
        items_per_report: usize,
    ) -> Self {
        assert!(items_per_report >= 1);
        Reporting {
            inner,
            workers: workers.clone(),
            items_per_report,
            autoscaler: None,
            replay: None,
            gateway: None,
            offline: None,
        }
    }

    /// Close the elasticity loop over the **worker pool**: the
    /// controller samples each report's telemetry (learner busy/idle
    /// interval ratio, sampler queue depth, weight-cast shed counters
    /// when a sole lane exists) and its directives are applied with
    /// `WorkerSet::scale_to` — an idle-learner workload converges to a
    /// larger sampler pool and a saturated one scales back down, with
    /// no manual `scale_to` calls.  Decision counters ride every
    /// `TrainResult::autoscale`; a failed apply (learner dead,
    /// registry full) is counted, not fatal.
    pub fn autoscale(mut self, controller: Autoscaler) -> Self {
        self.autoscaler = Some(controller);
        self
    }

    /// Attach a replay tier: every report snapshots the
    /// [`ReplayService`]'s backlog telemetry into
    /// `TrainResult::replay`, and — when `controller` is given — runs
    /// one replay control step per report (`Autoscaler::replay_signals`
    /// + `decide_replay`) and applies its directive with
    /// `ReplayService::scale_to`, closing the elasticity loop over the
    /// **replay-shard pool**.  The controller is an independent
    /// instance from [`Reporting::autoscale`]'s (counters land in
    /// `TrainResult::replay_autoscale` vs `TrainResult::autoscale`).
    pub fn replay(
        mut self,
        service: &ReplayService,
        controller: Option<Autoscaler>,
    ) -> Self {
        self.replay = Some((service.clone(), controller));
        self
    }

    /// Attach an external-episode gateway tier: every report snapshots
    /// the [`GatewayService`]'s backlog telemetry (sessions held,
    /// pending requests, p99 action latency, admission sheds, batch
    /// fill) into `TrainResult::gateway`, and — when `controller` is
    /// given — runs one gateway control step per report
    /// (`Autoscaler::gateway_signals` + `decide_gateway`) and applies
    /// its directive with `GatewayService::scale_to`, making gateway
    /// backlog the third autoscaled axis next to the sampler and
    /// replay pools.
    pub fn gateway(
        mut self,
        service: &GatewayService,
        controller: Option<Autoscaler>,
    ) -> Self {
        self.gateway = Some((service.clone(), controller));
        self
    }

    /// Attach an offline log-ingestion tier: every report snapshots the
    /// shared [`OfflineCounters`] the plan's `ops::read_from_logs`
    /// readers bump (frames/transitions/bytes ingested, corrupt and
    /// truncated frames, reader lag) into `TrainResult::offline`, with
    /// a decode rate (`frames_per_s`) computed over the report
    /// interval.
    pub fn offline(mut self, counters: Arc<OfflineCounters>) -> Self {
        self.offline = Some(counters);
        self
    }

    /// Finish the plan: the terminal `TrainResult` stream.
    pub fn build(self) -> LocalIter<TrainResult> {
        let Reporting {
            mut inner,
            workers,
            items_per_report,
            mut autoscaler,
            mut replay,
            mut gateway,
            offline,
        } = self;
        let mut hub = MetricsHub::new(100);
        let local = workers.local.clone();
        let registry = workers.registry().clone();
        let scale = workers.scale_counters();
        let fault_counters = workers.fault_counters();
        let set = workers;
        // (cumulative frames, when) at the previous report — the
        // interval base for the offline decode rate.
        let mut last_offline: Option<(u64, Instant)> = None;
        LocalIter::from_fn(move || {
            for _ in 0..items_per_report {
                let item = inner.next()?;
                hub.num_env_steps_trained += item.steps_trained as u64;
                hub.num_grad_updates += 1;
                for (k, v) in item.stats {
                    hub.record_learner_stat(&k, v);
                }
            }
            let handles = registry.handles();
            let mut snap =
                drain_and_snapshot(&mut hub, &local, &handles, |w| {
                    w.drain_metrics()
                });
            snap.weight_casts = set.sole_caster_stats();
            if let Some(a) = autoscaler.as_mut() {
                drive_autoscaler(a, &mut snap, &set, local.id(), &handles);
            }
            if let Some((service, controller)) = replay.as_mut() {
                let backlog = service.backlog_stats();
                snap.replay = Some(backlog);
                if let Some(a) = controller.as_mut() {
                    let signals = a.replay_signals(&backlog);
                    if let Some(d) = a.decide_replay(&signals) {
                        if service.scale_to(d.target).is_err() {
                            a.note_failed();
                        }
                    }
                    snap.replay_autoscale = Some(a.stats());
                }
            }
            if let Some((service, controller)) = gateway.as_mut() {
                let backlog = service.backlog_stats();
                snap.gateway = Some(backlog);
                if let Some(a) = controller.as_mut() {
                    let signals = a.gateway_signals(&backlog);
                    if let Some(d) = a.decide_gateway(&signals) {
                        if service.scale_to(d.target).is_err() {
                            a.note_failed();
                        }
                    }
                    snap.gateway_autoscale = Some(a.stats());
                }
            }
            if let Some(counters) = offline.as_ref() {
                let mut stats = counters.snapshot();
                let now = Instant::now();
                if let Some((prev_frames, prev_at)) = last_offline {
                    let dt = now.duration_since(prev_at).as_secs_f64();
                    if dt > 0.0 {
                        stats.frames_per_s =
                            stats.frames.saturating_sub(prev_frames) as f64
                                / dt;
                    }
                }
                last_offline = Some((stats.frames, now));
                snap.offline = Some(stats);
            }
            snap.scale =
                Some(scale.stats(registry.num_live(), registry.len()));
            snap.faults = Some(fault_counters.snapshot());
            Some(snap)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{DummyEnv, Env};
    use crate::ops::{parallel_rollouts_from, train_one_step};
    use crate::policy::DummyPolicy;
    use crate::rollout::{CollectMode, RolloutWorker};

    fn worker_set(n_remote: usize) -> WorkerSet {
        WorkerSet::new(n_remote, |_| {
            Box::new(|| {
                let envs: Vec<Box<dyn Env>> =
                    vec![Box::new(DummyEnv::new(4, 10))];
                RolloutWorker::new(
                    envs,
                    Box::new(DummyPolicy::new(0.1)),
                    10,
                    CollectMode::OnPolicy,
                )
            })
        })
    }

    #[test]
    fn reports_aggregate_training_and_episodes() {
        let workers = worker_set(2);
        let mut train = train_one_step(&workers);
        let train_op = parallel_rollouts_from(&workers)
            .gather_async(1)
            .for_each(move |b| train(b));
        let mut reports =
            Reporting::new(train_op, &workers, 2).build().take(3);
        let mut last = None;
        while let Some(r) = reports.next() {
            last = Some(r);
        }
        let r = last.unwrap();
        // 3 reports x 2 items x 10 steps trained.
        assert_eq!(r.num_env_steps_trained, 60);
        assert_eq!(r.num_grad_updates, 6);
        assert!(r.num_env_steps_sampled >= 60);
        assert!(r.episodes_total >= 4); // 10-step episodes on DummyEnv
        assert!(r.learner_stats.contains_key("loss"));
        // Pipeline telemetry rides along: exactly this plan's worker
        // actors appear (matched by id — the registry is global), with
        // work accounted to them.
        assert!(!r.actor_stats.is_empty());
        let remotes = workers.remotes();
        for h in remotes.iter().chain([&workers.local]) {
            let s = r
                .actor_stats
                .iter()
                .find(|s| s.id == h.id())
                .unwrap_or_else(|| panic!("no stats for {h:?}"));
            assert!(s.messages_processed > 0, "{s:?}");
            assert!(s.busy_ns > 0, "{s:?}");
            assert!(!s.poisoned);
        }
        // Weight-cast counters ride along too: one version per item.
        let wc = r.weight_casts.expect("weight-cast stats attached");
        assert_eq!(wc.version, 6);
        assert!(r.pipeline_summary().contains("weight_casts=v6"));
        // Scale events ride along (no events yet: 2 live, 2 slots).
        let sc = r.scale.expect("scale stats attached");
        assert_eq!((sc.added, sc.removed, sc.live, sc.slots), (0, 0, 2, 2));
        assert!(r.pipeline_summary().contains("scale=2/2slots"));
        // Fault counters ride along; a healthy run renders no section.
        let ft = r.faults.expect("fault stats attached");
        assert_eq!(ft, crate::actor::FaultStats::default());
        assert!(!r.pipeline_summary().contains("faults="));
    }

    #[test]
    fn replay_reports_attach_backlog_and_drive_shard_autoscaler() {
        use crate::actor::AutoscalerConfig;
        use crate::ops::create_replay_shards;
        use std::sync::atomic::Ordering::Relaxed;

        let workers = worker_set(1);
        let service = create_replay_shards(2, 4, 64, 16, 8);
        let controller = Autoscaler::new(AutoscalerConfig {
            min_workers: 1,
            max_workers: 4,
            cooldown_reports: 0,
            confirm_reports: 1,
            replay_idle_polls: 8,
            ..AutoscalerConfig::default()
        });
        let mut train = train_one_step(&workers);
        let train_op = parallel_rollouts_from(&workers)
            .gather_async(1)
            .for_each(move |b| train(b));
        let mut reports = Reporting::new(train_op, &workers, 1)
            .replay(&service, Some(controller))
            .build();

        // Report 1: a quiet tier — backlog telemetry attached, no
        // directive (empty mailboxes, no idle pressure yet).
        let r = reports.next().unwrap();
        let backlog = r.replay.expect("backlog stats attached");
        assert_eq!(backlog.live_shards, 2);
        let a = r.replay_autoscale.expect("controller stats attached");
        assert_eq!(a.decisions_up + a.decisions_down, 0);
        assert!(r.pipeline_summary().contains("replay=2shards"), "{r:?}");

        // Sustained not-ready pressure (the replay stream starving
        // below learning_starts across the whole pool): the controller
        // must emit a Down directive and the reporting operator must
        // apply it to the shard set.
        service.counters().not_ready.fetch_add(50, Relaxed);
        let r = reports.next().unwrap();
        let a = r.replay_autoscale.unwrap();
        assert_eq!(a.decisions_down, 1);
        assert_eq!(a.last_target, 1);
        assert_eq!(service.num_live_shards(), 1);
        assert_eq!(a.failed, 0);
        assert!(
            r.pipeline_summary().contains("replay_autoscale=t1"),
            "{}",
            r.pipeline_summary()
        );

        // Quiet again: the pool holds at the new size (no flapping).
        let r = reports.next().unwrap();
        assert_eq!(r.replay_autoscale.unwrap().decisions_down, 1);
        assert_eq!(service.num_live_shards(), 1);
    }

    #[test]
    fn offline_reports_attach_counters_and_interval_rate() {
        use std::sync::atomic::Ordering::Relaxed;

        let workers = worker_set(1);
        let counters = OfflineCounters::new();
        counters.frames.store(10, Relaxed);
        counters.transitions.store(320, Relaxed);
        counters.lag_bytes.store(512, Relaxed);
        let mut train = train_one_step(&workers);
        let train_op = parallel_rollouts_from(&workers)
            .gather_async(1)
            .for_each(move |b| train(b));
        let mut reports = Reporting::new(train_op, &workers, 1)
            .offline(counters.clone())
            .build();
        let r = reports.next().unwrap();
        let o = r.offline.expect("offline stats attached");
        assert_eq!(o.frames, 10);
        assert_eq!(o.transitions, 320);
        assert_eq!(o.lag_bytes, 512);
        assert_eq!(o.frames_per_s, 0.0); // no interval base yet
        assert!(r.pipeline_summary().contains("offline="), "{r:?}");
        // Second report: 30 more frames over a measurable interval.
        counters.frames.fetch_add(30, Relaxed);
        std::thread::sleep(std::time::Duration::from_millis(20));
        let r = reports.next().unwrap();
        let o = r.offline.unwrap();
        assert_eq!(o.frames, 40);
        assert!(o.frames_per_s > 0.0, "{o:?}");
    }

    #[test]
    fn reports_survive_worker_death_mid_plan() {
        // Kill a rollout worker while the plan is running: the driver
        // must keep producing reports off the survivors (the gather
        // retires the dead shard; metrics draining skips it) and the
        // report must expose the death through actor_stats.
        let workers = worker_set(2);
        let mut train = train_one_step(&workers);
        let train_op = parallel_rollouts_from(&workers)
            .gather_async(1)
            .for_each(move |b| train(b));
        let mut reports = Reporting::new(train_op, &workers, 1).build();
        assert!(reports.next().is_some());

        let victim = workers.remote(0).expect("live remote");
        assert!(victim.call(|_| -> () { panic!("fault injection") }).is_err());
        assert!(victim.await_poisoned(std::time::Duration::from_secs(2)));

        let mut last = None;
        for _ in 0..3 {
            last = reports.next();
            assert!(last.is_some(), "driver stopped reporting after a fault");
        }
        let r = last.unwrap();
        let dead = r
            .actor_stats
            .iter()
            .find(|s| s.id == victim.id())
            .expect("victim still registered");
        assert!(dead.poisoned);
        assert!(r.pipeline_summary().contains("dead="));
        // The surviving worker keeps sampling.
        assert!(!workers.remote(1).expect("live remote").is_poisoned());
    }
}
