//! RL-specific dataflow operators — the "RLlib Flow core" of Figure 2
//! (1118 LoC in the paper's implementation).
//!
//! Each operator is a small composable piece: either a constructor for a
//! `ParIter`/`LocalIter` source, or a closure factory meant to be handed
//! to `for_each`/`combine`.  Algorithms (see `crate::algorithms`) are
//! nothing but short compositions of these — which is the paper's whole
//! point.

mod gateway_ops;
mod metrics_ops;
mod offline_ops;
mod replay_ops;
mod rollout_ops;
mod train_ops;

use std::collections::BTreeMap;

pub use gateway_ops::{
    create_gateway_shards, gateway_experience, GatewayActorState,
    GatewayCounters, GatewayService, GatewaySession, GatewayShardGauge,
    DEFAULT_GATEWAY_EXPERIENCE_BACKOFF_BASE,
    DEFAULT_GATEWAY_EXPERIENCE_BACKOFF_CAP,
    DEFAULT_GATEWAY_POLL_BACKOFF_BASE, DEFAULT_GATEWAY_POLL_BACKOFF_CAP,
};
pub use metrics_ops::Reporting;
pub(crate) use metrics_ops::{drain_and_snapshot, drive_autoscaler};
pub use offline_ops::{
    log_frames, ope_estimate, read_from_logs, read_from_logs_with_backoff,
    OpeReport, DEFAULT_LOG_BACKOFF_BASE, DEFAULT_LOG_BACKOFF_CAP,
};
pub use replay_ops::{
    create_replay_actors, create_replay_shards, replay, replay_with_backoff,
    store_to_replay_buffer, ReplayActor, ReplayCounters, ReplayLease,
    ReplayService, DEFAULT_REPLAY_BACKOFF_BASE, DEFAULT_REPLAY_BACKOFF_CAP,
};
pub use rollout_ops::{
    concat_batches, exact_batches, parallel_ma_rollouts_from,
    parallel_rollouts, parallel_rollouts_from, select_policy,
};
pub use train_ops::{
    apply_gradients, compute_gradients, train_one_step, update_target_network,
};

/// The item type flowing between training operators: stats plus step
/// counters (feeds the [`Reporting`] tail).
#[derive(Debug, Clone, Default)]
pub struct TrainItem {
    pub stats: BTreeMap<String, f64>,
    pub steps_trained: usize,
}

impl TrainItem {
    pub fn new(stats: BTreeMap<String, f64>, steps_trained: usize) -> Self {
        TrainItem { stats, steps_trained }
    }
}
